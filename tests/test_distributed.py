"""Drive the multi-device integration checks in an isolated subprocess so
the main pytest process keeps the single real CPU device (the dry-run's 512
placeholder devices are likewise process-local)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


def run_checks(*names, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "distributed_checks.py"), *names],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


@pytest.mark.parametrize("check", [
    "check_expert_parallel_schedules",
    "check_a2a_pipelined_token_exact",
    "check_padded_experts_dead_on_mesh",
    "check_expert_replication_overlap",
    "check_serving_engine_on_mesh",
    "check_quantized_weights_on_mesh",
    "check_cp_decode_int8_cache",
    "check_cp_decode_matches_single_device",
    "check_cp_decode_ring_window",
    "check_sharded_train_step_matches_single",
    "check_params_pspec_structure",
    "check_data_sharded_batch",
    "check_analysis_rules_on_mesh",
])
def test_distributed(check):
    out = run_checks(check)
    assert f"PASS {check.replace('check_', '').split('_matches')[0]}" in out \
        or "ALL_OK" in out

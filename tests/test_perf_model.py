"""The paper's analytical model must reproduce its own published numbers."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import perf_model as pm


def test_table6_reproduced():
    """Paper Table 6: estimated bounds for 2..8 Mac Studio nodes @ 10 GbE.

    The paper's printed columns are internally inconsistent at the last
    digit (e.g. row 3 prints Load .055 + Comp .001 + Lat .040 + Trans .002
    yet Time 0.096), so we assert the reproduction within 1% of every
    published Time/TP value rather than exact string equality.
    """
    rows = {r["nodes"]: r for r in pm.scaling_table()}
    expect_tp = {2: 9.7, 3: 10.4, 4: 12.3, 6: 13.9, 8: 14.2}
    expect_time = {2: 0.103, 3: 0.096, 4: 0.081, 6: 0.072, 8: 0.070}
    for n in expect_tp:
        assert abs(rows[n]["tokens_per_sec"] - expect_tp[n]) / expect_tp[n] < 0.01
        assert abs(rows[n]["bound_s"] - expect_time[n]) < 1.2e-3


def test_table6_breakdown_columns():
    rows = {r["nodes"]: r for r in pm.scaling_table()}
    # Table 6 load column: 0.061 / 0.055 / 0.040 / 0.031 / 0.029
    expect_load = {2: 0.061, 3: 0.055, 4: 0.040, 6: 0.031, 8: 0.029}
    for n, load in expect_load.items():
        assert abs(rows[n]["load_s"] - load) < 1.5e-3, (n, rows[n]["load_s"])
        assert abs(rows[n]["lat_s"] - 0.040) < 1e-9
        assert abs(rows[n]["trans_s"] - 0.0016) < 2e-4


def test_table5_cost_efficiency():
    t5 = pm.paper_table5()
    assert round(t5["databricks-8xh100"], 6) == 0.000389
    assert round(t5["ours-2xm2ultra"], 6) == 0.000447
    # the headline claim: 1.15x more cost-efficient
    assert round(t5["ours-2xm2ultra"] / t5["databricks-8xh100"], 2) == 1.15


def test_table1_derivations_from_dbrx_config():
    """MoEWorkload.from_config(dbrx) must reproduce Table 1's derived
    variables within the paper's own rounding."""
    w = pm.MoEWorkload.from_config(get_config("dbrx"))
    assert abs(w.params_sa_bytes - 7e9) / 7e9 < 0.15       # ~7 GB
    assert abs(w.flops_sa - 14e9) / 14e9 < 0.15
    assert abs(w.params_expert_bytes - 16e9) / 16e9 < 0.05  # ~16 GB
    assert abs(w.flops_expert - 16e9) / 16e9 < 0.05
    assert abs(w.comm_bytes - 2e6) / 2e6 < 0.05


def test_rdma_projection_improves_two_node_throughput():
    """Fig. 8: RoCEv2/IB NICs lift the 2-node bound from ~9.7 to ~16.3."""
    base = pm.estimate(pm.DBRX_TABLE1, pm.M2_ULTRA_10GBE, 2).throughput
    roce = pm.estimate(pm.DBRX_TABLE1, pm.M2_ULTRA_ROCE, 2).throughput
    ib = pm.estimate(pm.DBRX_TABLE1, pm.M2_ULTRA_IB, 2).throughput
    assert round(base, 1) == 9.7
    assert 15.5 < roce < 17.0
    assert 15.5 < ib < 17.0


def test_gpu_term_is_load_dominated():
    """Paper: 'In most cases, the maximum is the load time' — memory-bound."""
    for n in (2, 3, 4, 6, 8):
        e = pm.estimate(pm.DBRX_TABLE1, pm.M2_ULTRA_10GBE, n)
        assert e.load_time > e.compute_time


def test_latency_dominates_transfer_on_10gbe():
    """Paper §3.1: network latency matters more than bandwidth."""
    e = pm.estimate(pm.DBRX_TABLE1, pm.M2_ULTRA_10GBE, 2)
    assert e.latency_time > 10 * e.transfer_time


def test_tpu_regime_inversion():
    """On TPU v5e ICI the comm term is bandwidth-dominated — the paper's
    latency-dominated regime inverts (docs/DESIGN.md §2)."""
    e = pm.estimate(pm.DBRX_TABLE1, pm.TPU_V5E, 16)
    assert e.latency_time < e.transfer_time


def test_overlap_term_models_pipelined_schedule():
    """estimate(..., microchunks=m): m=1 reproduces the serial Eq. (1);
    m>1 bounds the token at m*latency + max(gpu, transfer) +
    min(gpu, transfer)/m — never better than the exposed slower stage,
    never worse than the serial sum when latency is negligible."""
    w, hw = pm.DBRX_TABLE1, pm.M2_ULTRA_ROCE
    serial = pm.estimate(w, hw, 2)
    assert pm.estimate(w, hw, 2, microchunks=1).total == serial.total
    for m in (2, 4, 8):
        e = pm.estimate(w, hw, 2, microchunks=m)
        assert e.total >= max(e.gpu_time, e.transfer_time)
        expected = (e.latency_time * m + max(e.gpu_time, e.transfer_time)
                    + min(e.gpu_time, e.transfer_time) / m)
        assert abs(e.total - expected) < 1e-12
    # zero-latency hardware: overlap strictly beats serial and improves
    # monotonically with m
    hw0 = pm.HardwareProfile("lat0", hw.mem_bw, hw.peak_flops, 0.0, hw.comm_bw)
    totals = [pm.estimate(w, hw0, 2, microchunks=m).total
              for m in (1, 2, 4, 8)]
    assert all(a > b for a, b in zip(totals, totals[1:]))
    # on 10 GbE the per-round latency dominates: microchunking HURTS —
    # the model must show the regime, not just the win
    assert pm.estimate(w, pm.M2_ULTRA_10GBE, 2, microchunks=8).total \
        > pm.estimate(w, pm.M2_ULTRA_10GBE, 2).total


def test_scalability_trend_matches_table4():
    """Throughput increases with nodes but sublinearly (comm share grows)."""
    tps = [pm.estimate(pm.DBRX_TABLE1, pm.M2_ULTRA_10GBE, n).throughput
           for n in (2, 3, 4)]
    assert tps[0] < tps[1] < tps[2]
    assert tps[2] / tps[0] < 2.0  # far from linear scaling

    comm_frac = [pm.estimate(pm.DBRX_TABLE1, pm.M2_ULTRA_10GBE, n).comm_time
                 / pm.estimate(pm.DBRX_TABLE1, pm.M2_ULTRA_10GBE, n).total
                 for n in (2, 3, 4)]
    assert comm_frac[0] < comm_frac[1] < comm_frac[2]


def test_mixed_step_estimate_amortizes_weight_loads():
    """ISSUE 3 satellite: the unified mixed-batch iteration bound.  Adding
    a prefill chunk to a decode iteration grows the load term SUBLINEARLY
    (distinct experts saturate) while FLOPs/comm grow linearly — so on the
    paper's load-bound hardware a mixed iteration costs far less than a
    separate prefill program of the same size."""
    w, hw = pm.DBRX_TABLE1, pm.M2_ULTRA_10GBE
    dec_only = pm.mixed_step_estimate(w, hw, 2, decode_rows=4, chunk_len=0)
    mixed = pm.mixed_step_estimate(w, hw, 2, decode_rows=4, chunk_len=64)
    sep_prefill = pm.mixed_step_estimate(w, hw, 2, decode_rows=0,
                                         chunk_len=64)
    # chunk rides on weights the decode rows already paid to load
    assert mixed.total < dec_only.total + sep_prefill.total
    # load term saturates: 64 extra tokens cost < 64x the per-token load
    assert mixed.load_time < dec_only.load_time * 64
    # FLOPs are linear in tokens
    assert mixed.compute_time > dec_only.compute_time
    # only the total token count matters, not the decode/prefill split:
    # 4+0, 2+2 and 0+4 tokens are the same iteration
    assert dec_only.total == pm.mixed_step_estimate(
        w, hw, 2, decode_rows=2, chunk_len=2).total
    assert dec_only.total == pm.mixed_step_estimate(
        w, hw, 2, decode_rows=0, chunk_len=4).total


def test_chunked_prefill_ttft_tradeoff():
    """Smaller chunks mean more iterations, each paying the per-layer
    collective latency: TTFT of the prompt itself monotonically worsens as
    chunk_len shrinks — the cost side of the stall-free scheduler (the
    benefit side, decode latency, is bounded by the smaller per-iteration
    block)."""
    w, hw = pm.DBRX_TABLE1, pm.M2_ULTRA_10GBE
    ttfts = [pm.chunked_prefill_ttft(w, hw, 2, prompt_len=256, chunk_len=c)
             for c in (256, 64, 16)]
    assert ttfts[0] < ttfts[1] < ttfts[2]
    # one whole-prompt chunk == a single mixed iteration of that size
    assert ttfts[0] == pm.mixed_step_estimate(
        w, hw, 2, decode_rows=0, chunk_len=256).total


def test_kv_bytes_per_token_matches_cache_leaves():
    """The memory-capacity term's bytes/token must equal the real cache
    allocation (contiguous AND paged layouts allocate the same bytes per
    token slot; int8 adds the per-(token, head) fp32 scales)."""
    import jax

    from repro.configs.base import get_config
    from repro.models.model import build_model

    for kvd in ("native", "int8"):
        cfg = get_config("qwen3_moe_30b_a3b").reduced().replace(
            kv_cache_dtype=kvd)
        model = build_model(cfg)
        cache = model.init_cache(2, 16)
        nbytes = sum(a.size * a.dtype.itemsize
                     for a in jax.tree.leaves(cache))
        per_tok = pm.kv_bytes_per_token(cfg, precision=4)  # reduced = fp32
        assert per_tok == nbytes / (2 * 16)
        paged = model.init_paged_cache(8, 4)               # same 32 slots
        assert sum(a.size * a.dtype.itemsize
                   for a in jax.tree.leaves(paged)) == nbytes


def test_paged_concurrency_beats_contiguous_at_equal_pool_bytes():
    """ISSUE 4 memory-capacity term: at the paper's Table-2 unified-memory
    budget, the contiguous layout reserves max_cache slots per request
    while the paged layout reserves only page-rounded real context — more
    concurrent requests from the same bytes whenever contexts run short of
    max_cache."""
    bpt = pm.kv_bytes_per_token(n_layers=40, num_kv_heads=8, head_dim=128)
    pool = 0.25 * pm.M2_ULTRA_MEM_BYTES        # cache's share of 192 GB
    contiguous = pm.max_concurrent_requests(pool, bpt, mean_context=512,
                                            slot_len=4096)
    paged = pm.max_concurrent_requests(pool, bpt, mean_context=512,
                                       page_size=16)
    # ~8x (= 4096 / 512) up to the integer floor on each side
    assert 8 * contiguous <= paged <= 8 * (contiguous + 1)
    # page rounding only costs the tail page
    assert pm.max_concurrent_requests(pool, bpt, 510, page_size=16) == paged
    # at full-length contexts the two layouts converge
    assert pm.max_concurrent_requests(pool, bpt, 4096, page_size=16) \
        == contiguous
    cap = pm.serving_capacity(
        type("C", (), {"num_layers": 40, "num_kv_heads": 8, "head_dim": 128,
                       "kv_cache_dtype": "native"})(),
        pool_bytes=pool, max_cache=4096, mean_context=512, page_size=16)
    assert cap["paged"] > cap["contiguous"]
    assert cap["gain"] == pytest.approx(8.0, rel=0.02)


def test_weight_bytes_match_constructed_params():
    """ISSUE 5 satellite: ``model_weight_bytes`` (and the per-layer term)
    must equal the REAL byte count of the constructed params pytree under
    every quant level — same validation pattern as kv_bytes_per_token,
    via jax.eval_shape of quantize_params(model.init(...)).  Covers moe
    (router + expert stack), dense-with-tied-embeddings + qk_norm, and
    qkv_bias archs."""
    import jax

    from repro.core import quant
    from repro.models.model import build_model

    for arch in ("qwen3_moe_30b_a3b", "qwen3_0_6b", "stablelm_12b"):
        for level in ("none", "int8", "int4"):
            cfg = get_config(arch).reduced().replace(weight_quant=level)
            m = build_model(cfg)
            specs = jax.eval_shape(
                lambda r, m=m, cfg=cfg: quant.quantize_params(m.init(r),
                                                              cfg),
                jax.random.PRNGKey(0))
            real = quant.tree_bytes(specs)
            assert real == pm.model_weight_bytes(cfg), (arch, level)
    # per-layer term: L layers explain the whole model minus the shared
    # embed/lm_head/final_norm leaves
    cfg = get_config("qwen3_moe_30b_a3b").reduced().replace(
        weight_quant="int8")
    per_layer = pm.weight_bytes_per_layer(cfg)
    d, p = cfg.d_model, 4                       # reduced params are fp32
    shared = cfg.vocab_padded * d * p + d * p \
        + pm.quant_matrix_bytes(d, cfg.vocab_padded, itemsize=p,
                                quant="int8", block=cfg.weight_quant_block)
    assert shared + cfg.num_layers * per_layer == pm.model_weight_bytes(cfg)
    with pytest.raises(ValueError):
        pm.weight_bytes_per_layer(get_config("mamba2_130m").reduced())


def test_weight_bytes_match_engine_memory_stats():
    """The analytic model and the engine's reported device bytes agree —
    the satellite-2 cross-check wiring perf_model to memory_stats."""
    from repro.serving.engine import EngineConfig, ServingEngine

    for level in ("none", "int8", "int4"):
        cfg = get_config("qwen3_moe_30b_a3b").reduced().replace(
            weight_quant=level)
        eng = ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                              max_cache=32))
        ms = eng.memory_stats()
        assert ms["weight_bytes"] == pm.model_weight_bytes(cfg), level
        assert ms["kv_pool_bytes"] == 2 * 32 * pm.kv_bytes_per_token(
            cfg, precision=4)


def test_quant_levels_shrink_weight_bytes():
    """int8 >= 3.5x and int4 >= 6x smaller than fp on the CI config (fp
    router + embedding included in the total — the acceptance ratios),
    and the compression ratio of the quantized kinds alone approaches the
    ideal 4x / 8x as the block grows."""
    cfg = get_config("qwen3_moe_30b_a3b").reduced()
    fp = pm.model_weight_bytes(cfg, quant="none")
    assert fp / pm.model_weight_bytes(cfg, quant="int8") >= 3.5
    assert fp / pm.model_weight_bytes(cfg, quant="int4") >= 6.0
    # matrix-level: scale overhead shrinks with block size
    m8 = lambda b: pm.quant_matrix_bytes(1024, 1024, itemsize=4,
                                         quant="int8", block=b)
    assert m8(256) < m8(64) < pm.quant_matrix_bytes(1024, 1024, itemsize=4)
    assert abs(pm.quant_matrix_bytes(1024, 1024, itemsize=4) / m8(512)
               - 4.0) < 0.05


def test_max_model_at_budget_dbrx_headline():
    """The paper's Table-2 budget composed with the weight store: DBRX at
    bf16 does NOT fit one 192 GB M2 Ultra (263 GB/node) but DOES at int8
    (~136 GB); two nodes host it unquantized (the paper's own setup) —
    and the composed capacity term hands the leftover bytes to the KV
    pool."""
    dbrx = get_config("dbrx")
    one = pm.max_model_at_budget(dbrx, n_nodes=1)
    assert not one["fits"]["none"] and one["fits"]["int8"]
    assert one["level"] == "int8"
    two = pm.max_model_at_budget(dbrx, n_nodes=2)
    assert two["fits"]["none"] and two["level"] == "none"
    assert not pm.fits_in_memory(dbrx, n_nodes=1, quant="none")
    assert pm.fits_in_memory(dbrx, n_nodes=1, quant="int8")
    # headroom ordering is monotone in the quant level
    b = one["per_node_bytes"]
    assert b["none"] > b["int8"] > b["int4"]
    # composition with the PR-4 KV term: quantizing weights grows the KV
    # pool and with it the concurrent-request bound
    cap8 = pm.node_serving_capacity(dbrx, n_nodes=2, max_cache=4096,
                                    mean_context=512, page_size=16,
                                    quant="int8")
    cap_fp = pm.node_serving_capacity(dbrx, n_nodes=2, max_cache=4096,
                                      mean_context=512, page_size=16,
                                      quant="none")
    assert cap8["kv_pool_bytes"] > cap_fp["kv_pool_bytes"]
    assert cap8["paged"] > cap_fp["paged"]
    assert cap8["weight_bytes_per_node"] + cap8["kv_pool_bytes"] \
        == pm.M2_ULTRA_MEM_BYTES


def test_prefix_hit_ttft_skips_shared_pages_only():
    """Prefix hits shave exactly the page-aligned shared prefix off the
    modelled TTFT; a full-prompt hit still recomputes one token."""
    w, hw = pm.DBRX_TABLE1, pm.M2_ULTRA_10GBE
    base = pm.prefix_hit_ttft(w, hw, 2, prompt_len=256, shared_len=0,
                              chunk_len=64)
    assert base == pm.chunked_prefill_ttft(w, hw, 2, 256, 64)
    hit = pm.prefix_hit_ttft(w, hw, 2, prompt_len=256, shared_len=192,
                             chunk_len=64, page_size=16)
    assert hit < base
    assert hit == pm.chunked_prefill_ttft(w, hw, 2, 64, 64)
    # non-aligned shared length rounds DOWN to whole pages
    ragged = pm.prefix_hit_ttft(w, hw, 2, prompt_len=256, shared_len=200,
                                chunk_len=64, page_size=16)
    assert ragged == pm.chunked_prefill_ttft(w, hw, 2, 256 - 192, 64)
    # a fully-shared prompt still pays for >= 1 recomputed token
    full = pm.prefix_hit_ttft(w, hw, 2, prompt_len=256, shared_len=256,
                              chunk_len=64, page_size=1)
    assert full == pm.chunked_prefill_ttft(w, hw, 2, 1, 64)

"""ISSUE 3 tentpole: unified token-budget forward pass.

``Model.forward_routed`` processes an arbitrary (B, T) token block at
arbitrary per-row cache offsets — whole-prompt prefill, chunked prefill,
single-token decode and mixed prefill/decode batches are all the same
program.  These tests pin token-for-token equality against the two-program
reference (``EngineConfig.unified_step=False``) under non-binding capacity
(capacity pools are per-jit-call, so a binding capacity legitimately
drops different tokens per chunk — the batch-capacity semantics documented
in serving/engine.py), plus the no-truncation long-prompt path and
per-request sampling.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, input_specs, mixed_shape
from repro.models.model import build_model
from repro.serving.engine import EngineConfig, ServingEngine

MOE_ARCH = "qwen3_moe_30b_a3b"
DENSE_ARCH = "qwen3_0_6b"


def nocap(arch):
    """Reduced config with non-binding dispatch capacity (the regime where
    chunked == whole-prompt is exact; see module docstring)."""
    return get_config(arch).reduced().replace(capacity_factor=8.0)


def make_engine(cfg, seed=0, **eng_kw):
    kw = dict(max_batch=2, prefill_len=8, max_cache=32)
    kw.update(eng_kw)
    return ServingEngine(cfg, EngineConfig(**kw), rng=jax.random.PRNGKey(seed))


def generations(done):
    return {r.uid: list(r.generated) for r in done}


# ---------------------------------------------------------------------------
# model level: chunked forward_routed == whole-prompt prefill_routed
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", [MOE_ARCH, DENSE_ARCH])
@pytest.mark.parametrize("chunk", [3, 4, 8])   # 3 does not divide 8
def test_chunked_forward_matches_whole_prompt(arch, chunk):
    cfg = nocap(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s, c = 2, 8, 32
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 100, (b, s)),
                       jnp.int32)
    logits_r, cache_r, _ = model.prefill_routed(
        params, {"tokens": toks}, model.init_cache(b, c))
    cache_u = model.init_cache(b, c)
    for lo in range(0, s, chunk):
        hi = min(lo + chunk, s)
        logits_u, cache_u, routing = model.forward_routed(
            params, {"tokens": toks[:, lo:hi],
                     "lengths": jnp.full((b,), lo, jnp.int32),
                     "seg_lens": jnp.full((b,), hi - lo, jnp.int32)},
            cache_u)
        if cfg.is_moe:
            assert routing.shape == (cfg.num_layers, b * (hi - lo),
                                     cfg.experts_per_token)
    v = cfg.vocab_size
    np.testing.assert_array_equal(
        np.argmax(np.asarray(logits_r[:, -1, :v]), -1),
        np.argmax(np.asarray(logits_u[:, :v]), -1))
    # the caches agree exactly on every written slot
    np.testing.assert_allclose(np.asarray(cache_r["k"]),
                               np.asarray(cache_u["k"]), atol=1e-5)


def test_forward_routed_mixed_rows_match_decode_and_prefill():
    """One call whose rows do DIFFERENT work: row 0 decodes one token, row
    1 prefills a chunk — each must equal its single-purpose reference."""
    cfg = nocap(MOE_ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, c = 2, 32
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, 100, (b, 6)), jnp.int32)
    _, cache, _ = model.prefill_routed(params, {"tokens": toks},
                                       model.init_cache(b, c))
    # reference: row 0 decode step on the shared cache
    dec_tok = jnp.asarray([[7], [0]], jnp.int32)
    lengths = jnp.full((b,), 6, jnp.int32)
    logits_d, _, _ = model.decode_step_routed(
        params, jax.tree.map(jnp.copy, cache),
        {"tokens": dec_tok, "lengths": lengths,
         "token_mask": jnp.asarray([[True], [False]])})
    # reference: row 1 continues its prompt by 3 tokens (batch-1 unified
    # call — already verified equal to prefill by the test above)
    cont = jnp.asarray(rng.integers(0, 100, (1, 3)), jnp.int32)
    row1_cache = jax.tree.map(lambda a: a[:, 1:2] if a.ndim >= 2 else a,
                              cache)
    logits_p, _, _ = model.forward_routed(
        params, {"tokens": cont, "lengths": jnp.asarray([6], jnp.int32),
                 "seg_lens": jnp.asarray([3], jnp.int32)}, row1_cache)
    # mixed call: row 0 seg=1 (decode), row 1 seg=3 (prefill chunk)
    blk = jnp.zeros((b, 3), jnp.int32)
    blk = blk.at[0, 0].set(7).at[1].set(cont[0])
    logits_m, _, _ = model.forward_routed(
        params, {"tokens": blk, "lengths": jnp.asarray([6, 6], jnp.int32),
                 "seg_lens": jnp.asarray([1, 3], jnp.int32)}, cache)
    v = cfg.vocab_size
    assert int(jnp.argmax(logits_m[0, :v])) == int(
        jnp.argmax(logits_d[0, -1, :v]))
    assert int(jnp.argmax(logits_m[1, :v])) == int(
        jnp.argmax(logits_p[0, :v]))


def test_mixed_input_specs_match_forward_routed_signature():
    """configs.input_specs(kind="mixed") describes exactly the unified
    step's batch inputs (eval_shape-compatible with forward_routed)."""
    cfg = nocap(MOE_ARCH)
    model = build_model(cfg)
    shape = mixed_shape("mixed_demo", cache_len=32, batch=2, chunk_len=4)
    specs = input_specs(cfg, shape)
    assert set(specs) == {"tokens", "lengths", "seg_lens"}
    assert specs["tokens"].shape == (2, 4)
    p_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    c_sds = model.cache_specs(shape.global_batch, shape.seq_len)
    logits, _, _ = jax.eval_shape(model.forward_routed, p_sds, specs, c_sds)
    assert logits.shape == (2, cfg.vocab_padded)


def test_ring_cache_engine_falls_back_and_block_step_rejects_wide_chunks():
    """Ring caches (window == cache length) only take width-1 blocks: a
    wrapped multi-token write before attention would overwrite slots whose
    old positions are still inside earlier chunk tokens' windows.  The
    model raises loudly and the engine keeps the reference path."""
    cfg = nocap(MOE_ARCH).replace(sliding_window=16)
    eng = ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                          max_cache=32, unified_step=True),
                        rng=jax.random.PRNGKey(0))
    assert not eng.unified                    # cache clipped to a 16-ring
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(1, 32)           # -> ring of 16 slots
    with pytest.raises(ValueError, match="width-1"):
        model.forward_routed(
            params, {"tokens": jnp.zeros((1, 4), jnp.int32),
                     "lengths": jnp.zeros((1,), jnp.int32),
                     "seg_lens": jnp.full((1,), 4, jnp.int32)}, cache)


def test_engine_config_rejects_degenerate_scheduler_knobs():
    cfg = nocap(MOE_ARCH)
    for kw in (dict(chunk_len=0), dict(token_budget=-1)):
        with pytest.raises(ValueError, match="chunk_len must be"):
            ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                            max_cache=32, **kw))
    # an empty prompt would be scheduled as a decode row seeded from the
    # slot's stale last_tok — rejected at submit
    eng = make_engine(cfg)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros((0,), np.int32), max_new_tokens=2)


def test_forward_routed_rejects_stateful_families():
    cfg = get_config("mamba2_130m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(1, 16)
    with pytest.raises(NotImplementedError):
        model.forward_routed(
            params, {"tokens": jnp.zeros((1, 4), jnp.int32),
                     "lengths": jnp.zeros((1,), jnp.int32),
                     "seg_lens": jnp.full((1,), 4, jnp.int32)}, cache)


# ---------------------------------------------------------------------------
# engine level: unified scheduler == two-program reference, token for token
# ---------------------------------------------------------------------------

def _run_engine(cfg, prompts, max_new=5, **kw):
    eng = make_engine(cfg, **kw)
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    return generations(eng.run_until_done()), eng


@pytest.mark.parametrize("arch", [MOE_ARCH, DENSE_ARCH])
@pytest.mark.parametrize("chunk", [3, 8])      # 3 does not divide 8
def test_unified_engine_matches_reference(arch, chunk):
    """Full-length prompts (the padded reference attends its zero padding,
    so shorter prompts legitimately diverge) + non-binding capacity: the
    chunked/mixed-batch unified engine must be token-identical."""
    cfg = nocap(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 100, 8) for _ in range(4)]   # == prefill_len
    ref, _ = _run_engine(cfg, prompts, unified_step=False, async_steps=False)
    uni, eng = _run_engine(cfg, prompts, unified_step=True, chunk_len=chunk,
                           async_steps=False)
    assert eng.unified
    assert uni == ref
    # async dispatch and a binding per-iteration token budget only change
    # scheduling, never tokens
    uni_a, _ = _run_engine(cfg, prompts, unified_step=True, chunk_len=chunk,
                           async_steps=True)
    uni_b, _ = _run_engine(cfg, prompts, unified_step=True, chunk_len=chunk,
                           token_budget=chunk + 1)
    assert uni_a == ref and uni_b == ref


def test_unified_mixed_batch_matches_staggered_reference():
    """Arrivals mid-generation: the unified engine serves them as mixed
    prefill+decode iterations, the reference as separate programs — tokens
    must agree."""
    cfg = nocap(MOE_ARCH)
    rng = np.random.default_rng(7)
    p1, p2 = rng.integers(0, 100, 8), rng.integers(0, 100, 8)
    outs = {}
    for name, kw in (("ref", dict(unified_step=False)),
                     ("uni", dict(unified_step=True, chunk_len=3))):
        eng = make_engine(cfg, async_steps=False, **kw)
        eng.submit(p1, max_new_tokens=6)
        eng.step()
        eng.step()
        eng.submit(p2, max_new_tokens=4)     # lands mid-flight of p1
        outs[name] = generations(eng.run_until_done())
    assert outs["uni"] == outs["ref"]


def test_unified_serves_prompt_longer_than_prefill_len():
    """The acceptance-criteria scenario: a prompt LONGER than the reference
    prefill_len streams through the cache chunk by chunk, and generation
    equals a straight model-API replay of the untruncated prompt."""
    cfg = nocap(MOE_ARCH)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, 100, 21)               # > prefill_len=8
    eng = make_engine(cfg, max_batch=2, prefill_len=8, max_cache=64,
                      unified_step=True, chunk_len=5, async_steps=False)
    eng.submit(prompt, max_new_tokens=6)
    done = eng.run_until_done()
    assert len(done) == 1 and len(done[0].generated) == 6

    # reference replay: whole untruncated prompt through prefill_routed
    model = build_model(cfg)
    cache = model.init_cache(1, 64)
    logits, cache, _ = model.prefill_routed(
        eng.params, {"tokens": jnp.asarray(prompt[None], jnp.int32)}, cache)
    toks = [int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))]
    lengths = np.array([len(prompt)], np.int32)
    for _ in range(5):
        logits, cache, _ = model.decode_step_routed(
            eng.params, cache, {"tokens": jnp.asarray([[toks[-1]]]),
                                "lengths": jnp.asarray(lengths)})
        toks.append(int(jnp.argmax(logits[0, -1, :cfg.vocab_size])))
        lengths += 1
    assert done[0].generated == toks


def test_reference_mode_rejects_long_prompt():
    """Satellite fix: the padded reference engine must REFUSE prompts
    longer than prefill_len instead of silently dropping the prefix."""
    cfg = nocap(MOE_ARCH)
    eng = make_engine(cfg, unified_step=False)
    with pytest.raises(ValueError, match="refusing to silently truncate"):
        eng.submit(np.arange(9), max_new_tokens=2)      # prefill_len == 8
    # unified mode takes it, up to max_cache
    eng_u = make_engine(cfg, unified_step=True)
    eng_u.submit(np.arange(9), max_new_tokens=2)
    with pytest.raises(ValueError, match="refusing to silently truncate"):
        eng_u.submit(np.arange(33), max_new_tokens=2)   # max_cache == 32


def test_prefill_token_stats_count_real_tokens():
    """Satellite fix: prefill tok/s no longer counts padding as work."""
    cfg = nocap(MOE_ARCH)
    eng = make_engine(cfg, unified_step=False, async_steps=False)
    eng.submit(np.arange(5), max_new_tokens=2)          # 5 real, 3 pad
    eng.run_until_done()
    assert eng.stats["prefill_tokens"] == 5
    assert eng.stats["prefill_pad_tokens"] == 3
    tp = eng.throughput()
    assert tp["prefill_padding_overhead"] == pytest.approx(3 / 8)
    eng_u = make_engine(cfg, unified_step=True, chunk_len=4,
                        async_steps=False)
    eng_u.submit(np.arange(5), max_new_tokens=2)
    eng_u.run_until_done()
    assert eng_u.stats["prefill_tokens"] == 5
    assert eng_u.stats["prefill_pad_tokens"] == 0
    assert eng_u.throughput()["prefill_padding_overhead"] == 0.0


def test_unified_decode_rows_never_stall_on_admission():
    """A decode row advances one token on EVERY iteration, even the one
    that admits and prefills a fresh long prompt (the stall-free scheduler
    property; the reference engine runs a separate prefill program first)."""
    cfg = nocap(MOE_ARCH)
    eng = make_engine(cfg, max_batch=2, prefill_len=8, max_cache=64,
                      unified_step=True, chunk_len=4, async_steps=False)
    eng.submit(np.arange(4), max_new_tokens=10)
    eng.step()          # prefill (whole 4-token prompt fits one chunk)
    eng.step()          # decode 1... (token 1 sampled at prefill)
    r1 = eng._all[1]
    n_before = len(r1.generated)
    eng.submit(np.arange(24), max_new_tokens=2)   # long prompt arrives
    eng.step()          # mixed: r1 decodes WHILE r2's first chunk prefills
    assert len(r1.generated) == n_before + 1
    assert eng.prefill_pos[1] == 4                # r2 chunk 1 of 6 done
    done = eng.run_until_done()
    assert sorted(r.uid for r in done) == [1, 2]
    assert eng.stats["mixed_s"] > 0.0             # mixed batches happened
    assert eng.throughput()["decode_stall_s"] == 0.0


def test_token_budget_smaller_than_decode_rows_never_starves_prefill():
    """Decode rows are budget-EXEMPT: even with token_budget=1 and both
    slots decoding, a queued prompt must still make prefill progress once
    a slot frees — and in-flight decode must advance every iteration."""
    cfg = nocap(MOE_ARCH)
    eng = make_engine(cfg, max_batch=2, prefill_len=8, max_cache=32,
                      unified_step=True, chunk_len=4, token_budget=1,
                      async_steps=False)
    rng = np.random.default_rng(11)
    for _ in range(3):
        eng.submit(rng.integers(0, 100, 8), max_new_tokens=4)
    done = eng.run_until_done(max_steps=200)
    assert sorted(r.uid for r in done) == [1, 2, 3]
    assert all(len(r.generated) == 4 for r in done)


def test_unified_rejects_generation_overflowing_cache():
    """prompt + max_new_tokens must fit the cache: past max_cache the
    decode writes would be silently dropped and later tokens generated
    against a truncated context — reject at submit instead."""
    cfg = nocap(MOE_ARCH)
    eng = make_engine(cfg, unified_step=True)        # max_cache == 32
    eng.submit(np.arange(28), max_new_tokens=5)      # 28 + 5 - 1 == 32: ok
    with pytest.raises(ValueError, match="does not fit"):
        eng.submit(np.arange(28), max_new_tokens=6)  # 33 > 32


# ---------------------------------------------------------------------------
# per-request sampling
# ---------------------------------------------------------------------------

def test_stochastic_decode_deterministic_and_isolated():
    """temperature>0 rows sample (reproducibly, per sample_seed); rows at
    the default temperature=0 in the SAME batch stay exactly greedy."""
    cfg = nocap(MOE_ARCH)
    rng = np.random.default_rng(5)
    p_greedy, p_hot = rng.integers(0, 100, 8), rng.integers(0, 100, 8)

    def run(hot_temp):
        eng = make_engine(cfg, unified_step=True, chunk_len=8,
                          async_steps=False)
        u1 = eng.submit(p_greedy, max_new_tokens=6)
        u2 = eng.submit(p_hot, max_new_tokens=6, temperature=hot_temp,
                        top_k=16)
        g = generations(eng.run_until_done())
        return g[u1], g[u2]

    g0, h0 = run(0.0)
    g1, h1 = run(1.5)
    g2, h2 = run(1.5)
    assert g0 == g1 == g2            # greedy row untouched by neighbour
    assert h1 == h2                  # same seed -> same sample path
    assert h1 != h0                  # sampling actually changed tokens
    assert all(0 <= t < cfg.vocab_size for t in h1)


def test_sampling_works_in_reference_mode_too():
    cfg = nocap(MOE_ARCH)
    outs = []
    for _ in range(2):
        eng = make_engine(cfg, unified_step=False, async_steps=False)
        uid = eng.submit(np.arange(8) % 100, max_new_tokens=5,
                         temperature=0.9, top_k=8)
        outs.append(generations(eng.run_until_done())[uid])
    assert outs[0] == outs[1]
    assert all(0 <= t < cfg.vocab_size for t in outs[0])


# ---------------------------------------------------------------------------
# tracker integration
# ---------------------------------------------------------------------------

def test_unified_routing_capture_feeds_tracker():
    """Mixed batches dead-route padding to the E_pad sentinel; the tracker
    must only ever see real expert ids."""
    cfg = nocap(MOE_ARCH)
    eng = make_engine(cfg, unified_step=True, chunk_len=3, async_steps=False)
    rng = np.random.default_rng(9)
    eng.submit(rng.integers(0, 100, 8), max_new_tokens=4)
    eng.step()
    eng.submit(rng.integers(0, 100, 7), max_new_tokens=3)  # mixed iterations
    eng.run_until_done()
    assert eng.tracker is not None
    e2 = eng.expected_experts_per_node(2)
    assert 0.0 < e2 <= cfg.num_experts / 2 + 1e-9
    assert eng.tracker.exec_counts.shape == (cfg.num_layers, cfg.num_experts)
    assert eng.tracker.exec_counts.sum() > 0

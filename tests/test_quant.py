"""ISSUE 5: the blockwise int8/int4 quantized weight store (core/quant.py,
docs/DESIGN.md §8).

Property tests for the QuantTensor numeric policy (quantize→dequantize
error bound vs per-block max-abs, int4 pack/unpack exactness, zero-block
and degenerate-scale cases), the KV-cache wrapper dedupe (bit-identical to
the pre-refactor quantizer), and the argmax-equality gates:

  * the int8/int4 store is token-IDENTICAL to the *fake-quant fp
    reference* (an engine serving the pre-dequantized weights as raw
    arrays) — the machinery gate: every value the store dequantizes on
    the fly equals the reference's raw weight bit for bit, so any
    divergence is a store/plumbing bug, never quantization error;
  * vs RAW fp weights, int8 matches the greedy argmax on the overwhelming
    majority of positions (statistical bound — int8 rounding legitimately
    shifts logits ~1e-2, above occasional near-tie gaps, so exact raw-fp
    equality is not a sound gate; measured flip sites are true near-ties);
  * int4 stays within logit tolerance of fp;
  * ``weight_quant='none'`` round-trips through the store and the ckpt
    pipeline token-for-token.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:  # requirements-dev.txt; degrade to fixed samples when absent
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.base import get_config
from repro.core import quant
from repro.models.model import build_model
from repro.serving.engine import EngineConfig, ServingEngine

MOE_ARCH = "qwen3_moe_30b_a3b"


# ---------------------------------------------------------------------------
# QuantTensor numeric policy (property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(level=st.sampled_from(["int8", "int4"]),
       k=st.integers(1, 200), n=st.integers(1, 64),
       block=st.sampled_from([2, 16, 64, 128]),
       seed=st.integers(0, 2**16))
def test_quantize_dequantize_error_bound(level, k, n, block, seed):
    """|dequant(quant(w)) - w| <= per-block max-abs / (2 * qmax) per
    element: rounding moves each value at most half a quantization step,
    where the step is that BLOCK's absmax / qmax."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n),
                          jnp.float32) * 2.0
    qt = quant.quantize(w, level, block=block)
    assert qt.shape == (k, n)
    err = np.abs(np.asarray(qt.dequantize() - w))
    qmax = quant.QMAX[quant.BITS[level]]
    nb = -(-k // block)
    wpad = np.zeros((nb * block, n), np.float32)
    wpad[:k] = np.asarray(w)
    bmax = np.abs(wpad.reshape(nb, block, n)).max(axis=1)       # (nb, n)
    bound = np.repeat(bmax, block, axis=0)[:k] / (2 * qmax) + 1e-6
    assert (err <= bound).all(), (level, k, n, block, err.max())


@settings(max_examples=15, deadline=None)
@given(k=st.integers(1, 100), n=st.integers(1, 32),
       seed=st.integers(0, 2**16))
def test_int4_pack_unpack_roundtrip_exact(k, n, seed):
    """Nibble packing is lossless on the int4 value range [-7, 7],
    including odd reduction extents (zero-padded pair)."""
    q = jax.random.randint(jax.random.PRNGKey(seed), (k, n), -7, 8,
                           jnp.int8)
    rt = quant.unpack_int4(quant.pack_int4(q, axis=-2), axis=-2)
    assert np.array_equal(np.asarray(rt[:k]), np.asarray(q))
    if k % 2:  # the padded row unpacks to exactly zero
        assert (np.asarray(rt[k]) == 0).all()


@pytest.mark.parametrize("level", ["int8", "int4"])
def test_zero_block_and_degenerate_scale(level):
    """All-zero blocks produce zero scales and dequantize to exactly zero
    (the 1e-20 clamp keeps the round() finite); mixed zero/non-zero
    blocks only zero their own block."""
    w = jnp.zeros((128, 8), jnp.float32)
    qt = quant.quantize(w, level, block=64)
    assert (np.asarray(qt.scale) == 0).all()
    assert (np.asarray(qt.dequantize()) == 0).all()
    # block 0 zero, block 1 live
    w = w.at[64:].set(1.0)
    qt = quant.quantize(w, level, block=64)
    d = np.asarray(qt.dequantize())
    assert (d[:64] == 0).all() and np.allclose(d[64:], 1.0)
    # degenerate: a single huge outlier sets its block's scale; tiny
    # values in that block underflow to 0 but never NaN/inf
    w = jnp.full((64, 4), 1e-12, jnp.float32).at[0, 0].set(1e12)
    d = np.asarray(quant.quantize(w, level, block=64).dequantize())
    assert np.isfinite(d).all()


def test_quant_tensor_getitem_gathers_payload_and_scales():
    """Leading-axis expert gather (gather_moe's read): QuantTensor[idx]
    dequantizes to exactly dequantize(full)[idx]."""
    w = jax.random.normal(jax.random.PRNGKey(3), (6, 64, 16)) * 0.5
    for level in ("int8", "int4"):
        qt = quant.quantize(w, level, block=32)
        idx = jnp.asarray([[4, 0], [1, 5]])
        np.testing.assert_array_equal(
            np.asarray(qt[idx].dequantize()),
            np.asarray(qt.dequantize()[idx]))


def test_qdot_passthrough_is_bit_identical():
    """Raw weights through the qdot policy point == the plain einsum the
    call sites ran before the refactor."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (3, 5, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 32))
    np.testing.assert_array_equal(
        np.asarray(quant.qdot("bsd,df->bsf", x, w)),
        np.asarray(jnp.einsum("bsd,df->bsf", x, w)))
    np.testing.assert_array_equal(
        np.asarray(quant.qdot("bsd,df->bsf", x, w,
                              preferred_element_type=jnp.float32)),
        np.asarray(jnp.einsum("bsd,df->bsf", x, w,
                              preferred_element_type=jnp.float32)))


def test_kv_wrapper_bit_identical_to_seed_policy():
    """Satellite: attention.quantize_kv/dequantize_kv are thin wrappers
    over core/quant's absmax policy and must reproduce the pre-refactor
    per-(token, head) int8 KV quantizer bit for bit (the paged int8
    bit-exactness tests build on this)."""
    from repro.models import attention
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 9, 2, 64),
                          jnp.float32) * 3
    q, s = attention.quantize_kv(x)
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    q_seed = jnp.round(x / jnp.maximum(scale, 1e-20)).astype(jnp.int8)
    assert s.shape == scale.shape
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_seed))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(scale))
    np.testing.assert_array_equal(
        np.asarray(attention.dequantize_kv(q, s, jnp.bfloat16), np.float32),
        np.asarray((q_seed.astype(jnp.float32) * scale).astype(jnp.bfloat16),
                   np.float32))


# ---------------------------------------------------------------------------
# tree policy
# ---------------------------------------------------------------------------

def test_quantize_tree_policy_kinds():
    """Default kinds quantize attn/mlp/experts/lm_head; router, embedding,
    norms and biases stay raw; 'none' is the identity; the pipeline is
    idempotent."""
    cfg = get_config(MOE_ARCH).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    assert quant.quantize_tree(params, "none") is params
    qp = quant.quantize_params(params, cfg.replace(weight_quant="int8"))
    is_qt = lambda x: isinstance(x, quant.QuantTensor)
    assert is_qt(qp["lm_head"])
    assert not is_qt(qp["embed"])
    blocks = qp["blocks"]
    assert not is_qt(blocks["router"])
    assert not is_qt(blocks["ln1"])
    for kk in ("wq", "wk", "wv", "wo"):
        assert is_qt(blocks["attn"][kk])
    for kk in ("w_gate", "w_up", "w_down"):
        assert is_qt(blocks["experts"][kk])
    # idempotent
    qp2 = quant.quantize_params(qp, cfg.replace(weight_quant="int8"))
    assert all(a is b for a, b in zip(
        jax.tree.leaves(qp), jax.tree.leaves(qp2)))
    # per-kind override: keep experts fp too
    qp3 = quant.quantize_tree(params, "int8", kinds=("attn",))
    assert not is_qt(qp3["blocks"]["experts"]["w_gate"])
    assert is_qt(qp3["blocks"]["attn"]["wq"])
    with pytest.raises(ValueError):
        quant.quantize_tree(params, "int8", kinds=("embed",))
    with pytest.raises(ValueError):
        quant.quantize_tree(params, "int3")


def test_prestacked_quant_leaves_slice_through_scan():
    """QuantTensor leaves with a leading L axis ride lax.scan as xs:
    per-layer slices keep payload and scales in lockstep and dequantize
    to the per-layer slice of the full dequantization."""
    w = jax.random.normal(jax.random.PRNGKey(5), (3, 4, 64, 16)) * 0.3
    qt = quant.quantize(w, "int4", block=32)

    def body(c, lp):
        return c, lp.dequantize()

    _, per_layer = jax.lax.scan(body, 0, qt)
    np.testing.assert_array_equal(np.asarray(per_layer),
                                  np.asarray(qt.dequantize()))


# ---------------------------------------------------------------------------
# argmax-equality gates (serving)
# ---------------------------------------------------------------------------

def _run_engine(cfg, params, n_req=4, new_tokens=12, prompt_len=16,
                seed=0, **ecfg_kw):
    eng = ServingEngine(cfg, EngineConfig(
        max_batch=2, prefill_len=prompt_len,
        max_cache=prompt_len + new_tokens + 4, **ecfg_kw), params=params)
    rng = np.random.default_rng(seed)
    for _ in range(n_req):
        eng.submit(rng.integers(0, cfg.vocab_size, prompt_len),
                   max_new_tokens=new_tokens)
    return {r.uid: list(r.generated) for r in eng.run_until_done()}


@pytest.mark.parametrize("level", ["int8", "int4"])
def test_quantized_store_token_identical_to_fake_quant_reference(level):
    """THE machinery gate: the engine serving the QuantTensor store must
    generate exactly the tokens of an engine serving the pre-dequantized
    weights as raw fp arrays — the store's on-the-fly dequantization
    produces bit-identical operands, so argmax parity is mathematically
    guaranteed unless the plumbing (packing, scales, qdot call sites,
    scan slicing, donation) is broken."""
    base = get_config(MOE_ARCH).reduced()
    params = build_model(base).init(jax.random.PRNGKey(0))
    qcfg = base.replace(weight_quant=level)
    qp = quant.quantize_params(params, qcfg)
    toks_store = _run_engine(qcfg, params)           # quantize-on-load
    toks_ref = _run_engine(base, quant.dequantize_tree(qp))
    assert toks_store == toks_ref


def test_int8_decode_argmax_matches_fp_on_most_positions():
    """Vs RAW fp weights: int8 matches the greedy argmax on >= 90% of
    forward positions (measured ~97%).  Exact raw-fp equality is NOT
    gated — int8 rounding shifts logits by ~1e-2 and occasionally crosses
    a genuine near-tie (verified below: every flip site has a tiny fp
    top-2 margin), which is quantization error, not a store bug."""
    cfg = get_config(MOE_ARCH).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    qp = quant.quantize_params(params, cfg.replace(weight_quant="int8"))
    rng = np.random.default_rng(0)
    agree = total = 0
    margins = []
    for bseed in range(4):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
        lf, _ = m.forward(params, batch)
        lq, _ = m.forward(qp, batch)
        lf = np.asarray(lf[..., :cfg.vocab_size], np.float32)
        lq = np.asarray(lq[..., :cfg.vocab_size], np.float32)
        af, aq = lf.argmax(-1), lq.argmax(-1)
        agree += (af == aq).sum()
        total += af.size
        srt = np.sort(lf, axis=-1)
        margin = srt[..., -1] - srt[..., -2]
        margins.extend(margin[af != aq].tolist())
    assert agree / total >= 0.90, agree / total
    # every disagreement sits on a small top-2 margin relative to the
    # logit range (~4): measured flips cluster below 0.25
    assert all(mg < 0.5 for mg in margins), margins


def test_int4_within_logit_tolerance():
    """int4 (6x compression) stays within a coarse logit tolerance of fp —
    usable for capacity planning, looser than int8 by design."""
    cfg = get_config(MOE_ARCH).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    qp = quant.quantize_params(params, cfg.replace(weight_quant="int4"))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
    lf, _ = m.forward(params, batch)
    lq, _ = m.forward(qp, batch)
    diff = float(jnp.max(jnp.abs(lf - lq)))
    scale = float(jnp.max(jnp.abs(lf)))
    assert diff < scale, (diff, scale)          # same order as the logits
    assert diff < 16 * 0.5, diff                # and bounded absolutely


def test_weight_quant_none_roundtrips_through_store_and_ckpt():
    """weight_quant='none' is the identity through quantize_tree AND the
    ckpt save/restore path: token-for-token equal serving."""
    import os
    import tempfile

    from repro.ckpt import io

    cfg = get_config(MOE_ARCH).reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        io.save(path, params)
        restored, _ = io.quantize_on_load(path, cfg)  # weight_quant=none
    assert _run_engine(cfg, params) == _run_engine(cfg, restored)


def test_quantized_ckpt_roundtrip_token_identical():
    """A quantized store survives save/restore exactly: same QuantTensor
    meta, same payload bytes, same served tokens."""
    import os
    import tempfile

    from repro.ckpt import io

    cfg = get_config(MOE_ARCH).reduced().replace(weight_quant="int4")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    qp = quant.quantize_params(params, cfg)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        io.save(path, qp, step=3)
        rp, step = io.restore(path)
    assert step == 3
    assert jax.tree.structure(qp) == jax.tree.structure(rp)
    ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), qp, rp)
    assert all(jax.tree.leaves(ok))
    assert _run_engine(cfg, qp) == _run_engine(cfg, rp)


def test_memory_stats_reports_quantized_weight_bytes():
    """engine.memory_stats(): weight bytes shrink >= 3.5x at int8 (fp
    router/embedding) and >= 6x at int4; KV pool bytes are unchanged by
    weight quantization (satellite 2)."""
    base = get_config(MOE_ARCH).reduced()
    stats = {}
    for level in ("none", "int8", "int4"):
        eng = ServingEngine(base.replace(weight_quant=level),
                            EngineConfig(max_batch=2, prefill_len=8,
                                         max_cache=32))
        stats[level] = eng.memory_stats()
        assert stats[level]["weight_quant"] == level
    assert stats["none"]["weight_bytes"] / stats["int8"]["weight_bytes"] \
        >= 3.5
    assert stats["none"]["weight_bytes"] / stats["int4"]["weight_bytes"] \
        >= 6.0
    assert stats["none"]["kv_pool_bytes"] == stats["int8"]["kv_pool_bytes"]


def test_gather_decode_fast_path_with_quantized_store():
    """The capacity-free gather decode path reads only the selected
    experts' quantized payloads; it must match the dispatch path token
    for token on the same quantized store (the PR-2 gate, rerun under
    int8)."""
    outs = {}
    for tk in (64, 0):
        cfg = get_config(MOE_ARCH).reduced().replace(
            weight_quant="int8", gather_decode_max_tk=tk)
        outs[tk] = _run_engine(cfg, None, n_req=3, new_tokens=6,
                               prompt_len=7, seed=5)
    assert outs[64] == outs[0]


def test_use_kernel_quantized_matches_jnp_path():
    """cfg.use_kernel routes the quantized expert FFN through the Pallas
    in-kernel-dequant grouped GEMM (interpret mode on CPU) — model-level
    logits must match the jnp qdot path."""
    cfg = get_config(MOE_ARCH).reduced().replace(weight_quant="int8",
                                                 capacity_factor=8.0)
    m = build_model(cfg)
    params = quant.quantize_params(m.init(jax.random.PRNGKey(0)), cfg)
    rng = np.random.default_rng(2)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)}
    l0, _ = m.forward(params, batch)
    mk = build_model(cfg.replace(use_kernel=True))
    l1, _ = mk.forward(params, batch)
    np.testing.assert_allclose(np.asarray(l0, np.float32),
                               np.asarray(l1, np.float32),
                               rtol=2e-4, atol=2e-4)

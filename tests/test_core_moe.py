"""Unit + property tests for the paper's core: router, dispatch plan,
MoE strategies, prestacking, dynamic loading."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:  # requirements-dev.txt; degrade to fixed samples when absent
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import dynamic_load, moe, prestack, router


def rand_experts(key, e, d, f, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    s = 0.05
    return {"w_gate": jax.random.normal(ks[0], (e, d, f), dtype) * s,
            "w_up": jax.random.normal(ks[1], (e, d, f), dtype) * s,
            "w_down": jax.random.normal(ks[2], (e, f, d), dtype) * s}


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------

def test_router_topk_selects_highest_probs():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (16, 8))
    x = jax.random.normal(jax.random.fold_in(key, 1), (32, 16))
    out = router.route(w, x, k=2, norm_topk=False)
    probs = np.asarray(out.probs)
    for t in range(32):
        top2 = set(np.argsort(probs[t])[-2:])
        assert set(np.asarray(out.top_idx[t])) == top2


def test_router_dead_expert_masking():
    """Padded experts (granite 40->48) must never be selected."""
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (16, 12))
    x = jax.random.normal(jax.random.fold_in(key, 1), (64, 16))
    out = router.route(w, x, k=4, n_valid_experts=9)
    assert int(jnp.max(out.top_idx)) < 9
    assert float(jnp.sum(out.probs[:, 9:])) < 1e-6


def test_router_norm_topk_weights_sum_to_one():
    key = jax.random.PRNGKey(2)
    out = router.route(jax.random.normal(key, (8, 6)),
                       jax.random.normal(jax.random.fold_in(key, 1), (10, 8)),
                       k=3, norm_topk=True)
    np.testing.assert_allclose(np.asarray(jnp.sum(out.top_w, -1)), 1.0,
                               rtol=1e-5)


def test_aux_loss_uniform_routing_is_one():
    """Perfectly uniform router probs give aux = 1 (Switch normalization)."""
    t, e, k = 128, 8, 2
    probs = jnp.full((t, e), 1.0 / e)
    idx = jnp.stack([jnp.arange(t) % e, (jnp.arange(t) + 1) % e], -1)
    aux = router.load_balance_loss(probs, idx.astype(jnp.int32), e)
    np.testing.assert_allclose(float(aux), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# dispatch plan — hypothesis property tests
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    t=st.integers(1, 48),
    k=st.integers(1, 4),
    e=st.sampled_from([4, 8, 16]),
    cap=st.sampled_from([1, 2, 8, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dispatch_plan_properties(t, k, e, cap, seed):
    key = jax.random.PRNGKey(seed)
    top_idx = jax.random.randint(key, (t, k), 0, e).astype(jnp.int32)
    e_local = e // 2
    e_start = e_local            # second "node" owns experts [e/2, e)
    tok, valid, slot_of = moe.make_dispatch_plan(top_idx, e, e_start,
                                                 e_local, cap)
    tok, valid, slot_of = map(np.asarray, (tok, valid, slot_of))
    nbuf = e_local * cap
    top = np.asarray(top_idx)

    # 1. every valid slot holds a token routed to that slot's expert
    for s in range(nbuf):
        if valid[s]:
            expert = e_start + s // cap
            assert expert in top[tok[s]], (s, tok[s], expert)
    # 2. slot_of either points into the buffer at the right expert or == nbuf
    for tt in range(t):
        for kk in range(k):
            s = slot_of[tt, kk]
            expert = top[tt, kk]
            local = e_start <= expert < e_start + e_local
            if s < nbuf:
                assert local
                assert s // cap == expert - e_start
                assert valid[s] and tok[s] == tt
            else:
                assert s == nbuf
    # 3. no slot is claimed twice
    claimed = slot_of[slot_of < nbuf]
    assert len(np.unique(claimed)) == len(claimed)
    # 4. per-expert capacity respected
    for le in range(e_local):
        assert valid[le * cap:(le + 1) * cap].sum() <= cap


@settings(max_examples=30, deadline=None)
@given(
    t=st.integers(1, 48),
    k=st.integers(1, 4),
    e=st.sampled_from([4, 8, 16]),
    cap=st.sampled_from([1, 2, 4]),     # small: force capacity pressure
    seed=st.integers(0, 2**31 - 1),
)
def test_dispatch_drops_deterministic_and_stable_ordered(t, k, e, cap, seed):
    """Under capacity pressure, ``make_dispatch_plan`` drops must be (1)
    deterministic — identical plans on identical inputs — and (2)
    stable-ordered: each expert keeps exactly the FIRST ``cap`` routing
    decisions in flat row-major (t, k) order and drops the rest, with slot
    ranks following that order.  The serving engine's batched==sequential
    token equality and the a2a/decentralized schedule equivalence both rest
    on this invariant."""
    key = jax.random.PRNGKey(seed)
    top_idx = jax.random.randint(key, (t, k), 0, e).astype(jnp.int32)
    plan_a = moe.make_dispatch_plan(top_idx, e, 0, e, cap)
    plan_b = moe.make_dispatch_plan(top_idx, e, 0, e, cap)
    for a, b in zip(plan_a, plan_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    tok, valid, slot_of = map(np.asarray, plan_a)
    nbuf = e * cap
    flat = np.asarray(top_idx).reshape(-1)
    flat_slot = slot_of.reshape(-1)
    for ex in range(e):
        decisions = np.nonzero(flat == ex)[0]          # flat row-major order
        kept = [i for i in decisions if flat_slot[i] < nbuf]
        # first-come-first-kept, everything past capacity dropped
        assert kept == list(decisions[:cap])
        # ranks are assigned in arrival order within the expert's slots
        slots = [flat_slot[i] for i in kept]
        assert slots == sorted(slots)
        for i in decisions[cap:]:
            assert flat_slot[i] == nbuf                # dropped sentinel


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), t=st.sampled_from([8, 32]))
def test_dispatch_moe_matches_reference_at_high_capacity(seed, t):
    key = jax.random.PRNGKey(seed)
    e, d, f, k = 4, 16, 32, 2
    experts = rand_experts(jax.random.fold_in(key, 1), e, d, f)
    x = jax.random.normal(jax.random.fold_in(key, 2), (t, d))
    w = jax.random.normal(jax.random.fold_in(key, 3), (d, e))
    out = router.route(w, x, k)
    y_ref = moe.reference_moe(experts, x, out.top_idx, out.top_w)
    cap = moe.round_capacity(t, k, e, 8.0)
    y = moe.dispatch_moe(experts, x, out.top_idx, out.top_w, e, 0, cap)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


def test_dense_moe_matches_reference():
    key = jax.random.PRNGKey(7)
    e, d, f, k, t = 8, 16, 32, 2, 24
    experts = rand_experts(jax.random.fold_in(key, 1), e, d, f)
    x = jax.random.normal(jax.random.fold_in(key, 2), (t, d))
    w = jax.random.normal(jax.random.fold_in(key, 3), (d, e))
    out = router.route(w, x, k)
    y_ref = moe.reference_moe(experts, x, out.top_idx, out.top_w)
    # split into two "nodes" and sum partials — the paper's fork-join
    y = sum(moe.dense_moe(jax.tree.map(lambda a: a[n * 4:(n + 1) * 4], experts),
                          x, out.top_idx, out.top_w, e_start=n * 4)
            for n in range(2))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), t=st.sampled_from([1, 4, 16]))
def test_gather_moe_matches_reference(seed, t):
    """Capacity-free gather fast path == exact per-token reference, both on
    a single shard and as two half-shard partial sums."""
    key = jax.random.PRNGKey(seed)
    e, d, f, k = 8, 16, 32, 2
    experts = rand_experts(jax.random.fold_in(key, 1), e, d, f)
    x = jax.random.normal(jax.random.fold_in(key, 2), (t, d))
    w = jax.random.normal(jax.random.fold_in(key, 3), (d, e))
    out = router.route(w, x, k)
    y_ref = moe.reference_moe(experts, x, out.top_idx, out.top_w)
    y1 = moe.gather_moe(experts, x, out.top_idx, out.top_w, e_start=0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)
    y2 = sum(moe.gather_moe(jax.tree.map(lambda a: a[n * 4:(n + 1) * 4],
                                         experts),
                            x, out.top_idx, out.top_w, e_start=n * 4)
             for n in range(2))
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


def test_gather_moe_dead_sentinel_contributes_zero():
    """_mask_rout dead-routes tokens to index E (one past the padded expert
    range); the gather path must clip the index and zero the weight."""
    key = jax.random.PRNGKey(13)
    e, d, f = 4, 8, 16
    experts = rand_experts(key, e, d, f)
    x = jax.random.normal(jax.random.fold_in(key, 1), (3, d))
    top_idx = jnp.array([[0, 1], [e, e], [2, e]], jnp.int32)  # E = sentinel
    top_w = jnp.where(top_idx < e, 0.5, 0.0)
    y = moe.gather_moe(experts, x, top_idx, top_w, e_start=0)
    assert bool(jnp.all(jnp.isfinite(y)))
    np.testing.assert_allclose(np.asarray(y[1]), 0.0, atol=1e-7)


def test_capacity_drop_degrades_gracefully():
    """cap=1 drops tokens (paper: overflow) but output stays finite and
    close in norm for the surviving fraction."""
    key = jax.random.PRNGKey(8)
    e, d, f, k, t = 4, 8, 16, 2, 64
    experts = rand_experts(jax.random.fold_in(key, 1), e, d, f)
    x = jax.random.normal(jax.random.fold_in(key, 2), (t, d))
    w = jax.random.normal(jax.random.fold_in(key, 3), (d, e))
    out = router.route(w, x, k)
    y = moe.dispatch_moe(experts, x, out.top_idx, out.top_w, e, 0, 1)
    assert bool(jnp.all(jnp.isfinite(y)))


# ---------------------------------------------------------------------------
# prestacking (C2)
# ---------------------------------------------------------------------------

def test_prestack_roundtrip():
    key = jax.random.PRNGKey(9)
    blocks = {"w": jax.random.normal(key, (4, 8, 8)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (4, 8))}
    assert prestack.validate_roundtrip(blocks)


def test_pad_experts_router_dead():
    experts = {"w_gate": jnp.ones((5, 4, 8))}
    padded = prestack.pad_experts(experts, 8)
    assert padded["w_gate"].shape == (8, 4, 8)
    assert float(jnp.sum(jnp.abs(padded["w_gate"][5:]))) == 0.0


# ---------------------------------------------------------------------------
# dynamic loading (L_R host half)
# ---------------------------------------------------------------------------

def test_quota_topup_paper_example():
    """Fig. 6b: node2 selects 1 expert, node1 selects 3 -> node2 tops up to 3
    with its LRU experts."""
    sel = [[0, 1, 2], [5]]
    lru = [[3, 0, 1, 2], [7, 6, 4, 5]]
    out = dynamic_load.quota_topup(sel, lru)
    assert out[0] == [0, 1, 2]
    assert out[1] == [5, 7, 6]          # LRU order, skipping already-selected
    assert len(out[1]) == len(out[0])


def test_quota_topup_equal_loads_noop():
    sel = [[0, 1], [4, 5]]
    lru = [[2, 3, 0, 1], [6, 7, 4, 5]]
    assert dynamic_load.quota_topup(sel, lru) == [[0, 1], [4, 5]]


def test_lru_tracker_orders_by_staleness():
    tr = dynamic_load.LRUExpertTracker(num_layers=1, num_experts=4)
    tr.observe(0, [1]); tr.tick()
    tr.observe(0, [3]); tr.tick()
    tr.observe(0, [1]); tr.tick()
    order = list(tr.lru_order(0))
    # 0 and 2 never used (step 0), then 3 (step 1), then 1 (step 2)
    assert order.index(3) > order.index(0)
    assert order.index(1) > order.index(3)


def test_simulated_expected_experts_bounds():
    """E[#exec experts/node/layer] lies between the no-topup analytic value
    and E/n, and decreases with more nodes (paper Table 1 trend)."""
    vals = {}
    for n in (2, 4):
        v = dynamic_load.simulate_expected_experts(16, 4, n, n_tokens=300,
                                                   use_topup=False)
        lo = 0.9 * dynamic_load.np.float64(
            __import__("repro.core.perf_model", fromlist=["x"])
            .expected_experts_per_node(16, 4, n))
        assert v >= lo
        assert v <= 16 / n + 1e-9
        vals[n] = v
    assert vals[4] < vals[2]

"""ISSUE 2 tentpole (a): the serving hot loop is zero-copy in steady state.

The engine donates the cache operand of every jit
(``EngineConfig.donate_buffers``) and the model updates the cache with
``dynamic_update_slice`` on a scan *carry* (transformer._scan_stack_with_cache),
so the compiled decode program must alias the donated buffer in place.
These tests pin that at the HLO level via launch/hlo.py: the donated decode
step contains **no full-cache-sized copy op**, while the undonated baseline
provably does (regression contrast — the detector is not vacuous).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch import hlo
from repro.serving.engine import EngineConfig, ServingEngine

MOE_ARCH = "qwen3_moe_30b_a3b"
DENSE_ARCH = "qwen3_0_6b"


def compiled_decode(arch, donate, **cfg_kw):
    """Compile the engine's decode jit; returns (hlo_text, cache leaves)."""
    cfg = get_config(arch).reduced().replace(**cfg_kw)
    eng = ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                          max_cache=32,
                                          donate_buffers=donate))
    sds = lambda t: jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    ivec = jax.ShapeDtypeStruct((2,), jnp.int32)
    bvec = jax.ShapeDtypeStruct((2,), jnp.bool_)
    fvec = jax.ShapeDtypeStruct((2,), jnp.float32)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    txt = eng._jit_decode.lower(sds(eng.params), sds(eng.cache), ivec, ivec,
                                bvec, fvec, ivec, step,
                                False).compile().as_text()
    return txt, jax.tree.leaves(eng.cache)


def leaf_bytes(leaves):
    return [int(np.prod(a.shape)) * a.dtype.itemsize for a in leaves]


def compiled_unified(arch, donate, chunk_len=4, paged=False, page_size=8,
                     **cfg_kw):
    """Compile the engine's unified mixed-batch jit (ISSUE 3; ISSUE 4 with
    ``paged=True``); returns (hlo_text, cache leaves)."""
    cfg = get_config(arch).reduced().replace(**cfg_kw)
    eng = ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                          max_cache=32, unified_step=True,
                                          chunk_len=chunk_len,
                                          donate_buffers=donate,
                                          paged=paged, page_size=page_size))
    sds = lambda t: jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    ivec = jax.ShapeDtypeStruct((2,), jnp.int32)
    bvec = jax.ShapeDtypeStruct((2,), jnp.bool_)
    fvec = jax.ShapeDtypeStruct((2,), jnp.float32)
    toks = jax.ShapeDtypeStruct((2, chunk_len), jnp.int32)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    bt = (jax.ShapeDtypeStruct((2, eng.max_blocks), jnp.int32)
          if paged else None)
    txt = eng._jit_unified.lower(
        sds(eng.params), sds(eng.cache), toks, ivec, ivec, ivec, bt,
        bvec, bvec, fvec, ivec, step, False).compile().as_text()
    return txt, jax.tree.leaves(eng.cache)


@pytest.mark.parametrize("arch,kw", [
    # gather path off: its expert-weight gathers are larger than a cache
    # leaf and would trip the size threshold without touching the cache
    (MOE_ARCH, dict(gather_decode_max_tk=0)),
    (DENSE_ARCH, dict()),
])
def test_donated_decode_has_no_full_cache_copy(arch, kw):
    txt, leaves = compiled_decode(arch, donate=True, **kw)
    min_leaf = min(leaf_bytes(leaves))
    copies = hlo.sized_copies(txt, min_leaf)
    assert copies == [], copies
    # every cache leaf must be aliased to its donated input
    assert hlo.input_output_aliases(txt) >= len(leaves)


def test_donated_decode_with_gather_path_never_copies_cache_leaf():
    """Production MoE config (gather decode enabled): the only copies the
    program may contain are the gather path's selected-expert weight loads
    — never a buffer of a cache leaf's exact size."""
    txt, leaves = compiled_decode(MOE_ARCH, donate=True)
    sizes = set(leaf_bytes(leaves))
    offending = [c for c in hlo.sized_copies(txt, min(sizes))
                 if c[1] in sizes]
    assert offending == [], offending
    assert hlo.input_output_aliases(txt) >= len(leaves)


@pytest.mark.parametrize("arch,kw", [
    (MOE_ARCH, dict(gather_decode_max_tk=0)),
    (DENSE_ARCH, dict()),
])
def test_donated_unified_step_has_no_full_cache_copy(arch, kw):
    """ISSUE 3 satellite: the unified mixed-batch program keeps the
    zero-copy property — its per-row block writes are dynamic-slice
    read-modify-writes on the scan carry, so the donated cache still
    aliases in place with no full-cache-sized copy."""
    txt, leaves = compiled_unified(arch, donate=True, **kw)
    min_leaf = min(leaf_bytes(leaves))
    copies = hlo.sized_copies(txt, min_leaf)
    assert copies == [], copies
    assert hlo.input_output_aliases(txt) >= len(leaves)


def test_donated_unified_step_production_config_never_copies_cache_leaf():
    """Production MoE unified config (gather fast path may engage for tiny
    blocks): no copy of a cache leaf's exact size, all leaves aliased."""
    txt, leaves = compiled_unified(MOE_ARCH, donate=True)
    sizes = set(leaf_bytes(leaves))
    offending = [c for c in hlo.sized_copies(txt, min(sizes))
                 if c[1] in sizes]
    assert offending == [], offending
    assert hlo.input_output_aliases(txt) >= len(leaves)


@pytest.mark.parametrize("arch,kw", [
    (MOE_ARCH, dict(gather_decode_max_tk=0)),
    (DENSE_ARCH, dict()),
])
def test_donated_paged_step_has_no_pool_sized_copy(arch, kw):
    """ISSUE 4 tentpole pin: the paged unified program writes K/V via an
    in-place scatter on the scan-carry pool and reads it via block-table
    gathers — the donated program must contain NO pool-sized copy op (the
    gather's (B, NB*ps, Hkv, hd) result is a gather, not a copy, and is
    bounded by the per-row logical cache, exactly what the contiguous
    attention read)."""
    txt, leaves = compiled_unified(arch, donate=True, paged=True,
                                   page_size=8, **kw)
    min_leaf = min(leaf_bytes(leaves))
    copies = hlo.sized_copies(txt, min_leaf)
    assert copies == [], copies
    assert hlo.input_output_aliases(txt) >= len(leaves)


def test_donated_paged_step_production_config_never_copies_cache_leaf():
    """Production MoE paged config (gather fast path may engage): no copy
    of a pool leaf's exact size, every leaf aliased to its donated
    input."""
    txt, leaves = compiled_unified(MOE_ARCH, donate=True, paged=True,
                                   page_size=8)
    sizes = set(leaf_bytes(leaves))
    offending = [c for c in hlo.sized_copies(txt, min(sizes))
                 if c[1] in sizes]
    assert offending == [], offending
    assert hlo.input_output_aliases(txt) >= len(leaves)


def test_paged_cow_page_copy_is_page_sized_not_pool_sized():
    """The copy-on-write helper may copy exactly one page worth of rows
    per leaf — never a pool-sized buffer."""
    cfg = get_config(MOE_ARCH).reduced()
    eng = ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                          max_cache=32, paged=True,
                                          page_size=8))
    sds = lambda t: jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    one = jax.ShapeDtypeStruct((1,), jnp.int32)
    txt = eng._jit_copy_pages.lower(sds(eng.cache), one,
                                    one).compile().as_text()
    leaves = jax.tree.leaves(eng.cache)
    min_leaf = min(leaf_bytes(leaves))
    assert hlo.sized_copies(txt, min_leaf) == []


@pytest.mark.parametrize("level", ["int8", "int4"])
def test_donated_decode_quantized_weights_never_copies_cache_leaf(level):
    """ISSUE 5 acceptance: the donated decode program with the quantized
    weight store keeps the PR-2 zero-copy invariant — on-the-fly weight
    dequantization is converts/multiplies on weight-sized buffers, never
    a copy of a cache leaf's size, and every cache leaf still aliases its
    donated input."""
    txt, leaves = compiled_decode(MOE_ARCH, donate=True, weight_quant=level)
    sizes = set(leaf_bytes(leaves))
    offending = [c for c in hlo.sized_copies(txt, min(sizes))
                 if c[1] in sizes]
    assert offending == [], offending
    assert hlo.input_output_aliases(txt) >= len(leaves)


def test_donated_unified_step_quantized_weights_never_copies_cache_leaf():
    """Same pin for the unified mixed-batch program under int8 weights
    (the production serving path of the quantized store)."""
    txt, leaves = compiled_unified(MOE_ARCH, donate=True,
                                   weight_quant="int8")
    sizes = set(leaf_bytes(leaves))
    offending = [c for c in hlo.sized_copies(txt, min(sizes))
                 if c[1] in sizes]
    assert offending == [], offending
    assert hlo.input_output_aliases(txt) >= len(leaves)


def test_undonated_decode_copies_the_cache():
    """Regression contrast: without donation XLA MUST materialize the
    non-aliased cache (the paper's C1 memory-management overhead) — proves
    the copy detector actually detects."""
    txt, leaves = compiled_decode(MOE_ARCH, donate=False,
                                  gather_decode_max_tk=0)
    assert hlo.input_output_aliases(txt) == 0
    assert len(hlo.sized_copies(txt, min(leaf_bytes(leaves)))) >= 1


def test_donation_deletes_the_dispatched_cache_buffer():
    """Behavioral proof of donation: after a decode dispatch the previous
    cache buffer is consumed (deleted), not kept alive as a copy source."""
    cfg = get_config(MOE_ARCH).reduced()
    eng = ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                          max_cache=32))
    eng.submit(np.arange(6), max_new_tokens=4)
    eng.step()                      # admit + first decode step
    before = eng.cache
    eng.step()
    assert all(a.is_deleted() for a in jax.tree.leaves(before))
    eng.flush()
    done = [r for r in eng._all.values()]
    assert done and not any(a.is_deleted()
                            for a in jax.tree.leaves(eng.cache))


def test_donation_is_token_neutral():
    """Donation must never change values: donate on/off generate identical
    tokens on identical params/requests."""
    outs = {}
    for donate in (True, False):
        cfg = get_config(MOE_ARCH).reduced()
        eng = ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                              max_cache=32,
                                              donate_buffers=donate),
                            rng=jax.random.PRNGKey(0))
        rng = np.random.default_rng(3)
        for _ in range(3):
            eng.submit(rng.integers(0, 100, 6), max_new_tokens=5)
        outs[donate] = {r.uid: list(r.generated)
                        for r in eng.run_until_done()}
    assert outs[True] == outs[False]


def test_gather_decode_is_token_neutral():
    """The capacity-free gather decode path must generate the same tokens
    as the fixed-capacity dispatch on the same params (per-token MoE sums
    are mathematically identical; greedy argmax is stable to the fp
    reassociation)."""
    outs = {}
    for tk in (64, 0):
        cfg = get_config(MOE_ARCH).reduced().replace(gather_decode_max_tk=tk)
        eng = ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                              max_cache=32),
                            rng=jax.random.PRNGKey(0))
        rng = np.random.default_rng(5)
        for _ in range(3):
            eng.submit(rng.integers(0, 100, 7), max_new_tokens=6)
        outs[tk] = {r.uid: list(r.generated) for r in eng.run_until_done()}
    assert outs[64] == outs[0]

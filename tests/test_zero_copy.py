"""ISSUE 2 tentpole (a): the serving hot loop is zero-copy in steady state.

The engine donates the cache operand of every jit
(``EngineConfig.donate_buffers``) and the model updates the cache with
``dynamic_update_slice`` on a scan *carry* (transformer._scan_stack_with_cache),
so the compiled decode program must alias the donated buffer in place.

The HLO pins are expressed through analysis rule R1
(``repro.analysis.donation.DonationAliasRule`` over
``repro.analysis.programs.trace_program``), which is strictly stronger than
the original inline checks: every cache leaf must alias BY flat parameter
number (not just a surviving alias count), and the copy scan covers async
``copy-start``/``copy-done`` pairs as well as plain copies.  The undonated
baseline provably trips both checks (regression contrast — the detector is
not vacuous), and the behavioral tests below prove donation at runtime.
"""
import jax
import numpy as np
import pytest

from repro.analysis.donation import DonationAliasRule
from repro.analysis.programs import trace_program
from repro.configs.base import get_config
from repro.launch import hlo
from repro.serving.engine import EngineConfig, ServingEngine

MOE_ARCH = "qwen3_moe_30b_a3b"
DENSE_ARCH = "qwen3_0_6b"


@pytest.mark.parametrize("variant,arch,kw", [
    # gather path off: its expert-weight gathers are larger than a cache
    # leaf, so R1 applies the strict >=min-leaf copy threshold (see
    # TracedProgram.copy_exact_sizes); production MoE configs keep the
    # gather path on and R1 matches cache-leaf sizes exactly instead
    ("decode", MOE_ARCH, dict(gather_decode_max_tk=0)),
    ("decode", DENSE_ARCH, {}),
    ("decode", MOE_ARCH, {}),
    ("unified", MOE_ARCH, dict(gather_decode_max_tk=0)),
    ("unified", DENSE_ARCH, {}),
    ("unified", MOE_ARCH, {}),
    ("paged", MOE_ARCH, dict(gather_decode_max_tk=0)),
    ("paged", DENSE_ARCH, {}),
    ("paged", MOE_ARCH, {}),
    # quantized weight store (ISSUE 5): dequantization is converts and
    # multiplies on weight-sized buffers, never a cache-leaf-sized copy
    ("decode", MOE_ARCH, dict(weight_quant="int8")),
    ("decode", MOE_ARCH, dict(weight_quant="int4")),
    ("int8", MOE_ARCH, {}),
])
def test_donated_program_is_zero_copy(variant, arch, kw):
    prog = trace_program(variant, arch, cfg_kw=kw or None)
    findings = DonationAliasRule().check(prog)
    assert findings == [], [str(f) for f in findings]
    # R1's alias check is per-leaf; keep the coarse count pin too so a
    # rule regression can't silently weaken this test
    assert hlo.input_output_aliases(prog.hlo_text) >= len(prog.cache_bytes)


def test_paged_cow_page_copy_is_page_sized_not_pool_sized():
    """The copy-on-write helper may copy exactly one page worth of rows
    per leaf — never a pool-sized buffer."""
    cfg = get_config(MOE_ARCH).reduced()
    eng = ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                          max_cache=32, paged=True,
                                          page_size=8))
    sds = lambda t: jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    one = jax.ShapeDtypeStruct((1,), jax.numpy.int32)
    txt = eng._jit_copy_pages.lower(sds(eng.cache), one,
                                    one).compile().as_text()
    leaves = jax.tree.leaves(eng.cache)
    min_leaf = min(int(np.prod(a.shape)) * a.dtype.itemsize for a in leaves)
    assert hlo.sized_copies(txt, min_leaf) == []


def test_undonated_decode_flags_every_leaf_and_the_cache_copy():
    """Regression contrast: without donation XLA MUST materialize the
    non-aliased cache (the paper's C1 memory-management overhead) — R1
    names every unaliased leaf AND finds the full-cache-sized copy, which
    proves both halves of the detector actually detect."""
    prog = trace_program("decode", MOE_ARCH, donate=False,
                         cfg_kw=dict(gather_decode_max_tk=0),
                         name="decode-undonated")
    findings = DonationAliasRule().check(prog)
    missing = [f for f in findings if "leaf" in f.detail]
    copies = [f for f in findings if "line" in f.detail]
    assert len(missing) == len(prog.cache_bytes)
    assert copies, "undonated baseline must contain a cache-sized copy"
    assert hlo.input_output_aliases(prog.hlo_text) == 0


def test_donation_deletes_the_dispatched_cache_buffer():
    """Behavioral proof of donation: after a decode dispatch the previous
    cache buffer is consumed (deleted), not kept alive as a copy source."""
    cfg = get_config(MOE_ARCH).reduced()
    eng = ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                          max_cache=32))
    eng.submit(np.arange(6), max_new_tokens=4)
    eng.step()                      # admit + first decode step
    before = eng.cache
    eng.step()
    assert all(a.is_deleted() for a in jax.tree.leaves(before))
    eng.flush()
    done = [r for r in eng._all.values()]
    assert done and not any(a.is_deleted()
                            for a in jax.tree.leaves(eng.cache))


def test_donation_is_token_neutral():
    """Donation must never change values: donate on/off generate identical
    tokens on identical params/requests."""
    outs = {}
    for donate in (True, False):
        cfg = get_config(MOE_ARCH).reduced()
        eng = ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                              max_cache=32,
                                              donate_buffers=donate),
                            rng=jax.random.PRNGKey(0))
        rng = np.random.default_rng(3)
        for _ in range(3):
            eng.submit(rng.integers(0, 100, 6), max_new_tokens=5)
        outs[donate] = {r.uid: list(r.generated)
                        for r in eng.run_until_done()}
    assert outs[True] == outs[False]


def test_gather_decode_is_token_neutral():
    """The capacity-free gather decode path must generate the same tokens
    as the fixed-capacity dispatch on the same params (per-token MoE sums
    are mathematically identical; greedy argmax is stable to the fp
    reassociation)."""
    outs = {}
    for tk in (64, 0):
        cfg = get_config(MOE_ARCH).reduced().replace(gather_decode_max_tk=tk)
        eng = ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                              max_cache=32),
                            rng=jax.random.PRNGKey(0))
        rng = np.random.default_rng(5)
        for _ in range(3):
            eng.submit(rng.integers(0, 100, 7), max_new_tokens=6)
        outs[tk] = {r.uid: list(r.generated) for r in eng.run_until_done()}
    assert outs[64] == outs[0]

"""ISSUE 6: the static analyzer (repro.analysis) — clean on main, and every
rule provably fires on a planted violation.

Rules R1/R2/R6 are exercised against the real compiled decode program (one
shared trace) with violations spliced into its HLO text; R3 against a live
engine pushed through an undocumented retrace; R4 against planted engine
source; R5 against hand-built jaxprs around core/quant plus the real int8
unified jaxpr.  The CLI test runs the module end to end and checks the
machine-readable report CI gates on.
"""
import dataclasses
import json
import os
import pathlib
import subprocess
import sys
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import framework
from repro.analysis import programs as programs_lib
from repro.analysis.collectives import CollectiveBudgetRule
from repro.analysis.donation import DonationAliasRule
from repro.analysis.hostsync import HostSyncRule
from repro.analysis.quant_integrity import check_closed_jaxpr
from repro.analysis.retrace import RetraceRule, expected_trace_budget
from repro.analysis.sharding_lint import ShardingLintRule, \
    expert_gather_threshold
from repro.configs.base import get_config
from repro.core import perf_model, quant

ARCH = "qwen3_moe_30b_a3b"
REPO = pathlib.Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def decode_prog():
    return programs_lib.trace_program("decode", ARCH)


def _splice_into_entry(prog, line):
    """A copy of ``prog`` with ``line`` planted inside the ENTRY body."""
    lines = prog.hlo_text.splitlines()
    i = next(j for j, l in enumerate(lines)
             if l.lstrip().startswith("ENTRY"))
    lines.insert(i + 1, "  " + line)
    return dataclasses.replace(prog, hlo_text="\n".join(lines))


# ---------------------------------------------------------------------------
# framework


class _BoomRule(framework.Rule):
    rule_id = "RX"
    name = "boom"

    def check(self, prog):
        return [self.finding(prog.name, "boom", tag=1)]


def test_framework_report_and_warn_only():
    progs = [SimpleNamespace(name="p1"), SimpleNamespace(name="p2")]
    rep = framework.run_rules([_BoomRule()], progs)
    assert not rep.ok and len(rep.errors) == 2 and rep.by_rule("RX")
    demoted = framework.run_rules([_BoomRule()], progs, warn_only={"RX"})
    assert demoted.ok and len(demoted.warnings) == 2
    d = json.loads(demoted.to_json())
    assert d["ok"] and d["n_warnings"] == 2
    assert d["findings"][0]["detail"] == {"tag": 1}
    assert "RX" in str(demoted.findings[0])


# ---------------------------------------------------------------------------
# R1 donation-alias (clean/undonated cases live in test_zero_copy.py)


def test_r1_flags_every_leaf_when_alias_header_unparsable(decode_prog):
    broken = dataclasses.replace(
        decode_prog,
        hlo_text=decode_prog.hlo_text.replace(
            "input_output_alias={", "input_output_alias_disabled={", 1))
    findings = DonationAliasRule().check(broken)
    missing = [f for f in findings if "leaf" in f.detail]
    assert len(missing) == len(decode_prog.cache_bytes)
    # findings name the exact flat parameter so the fix is mechanical
    assert all(f.detail["param_number"] >= decode_prog.n_param_leaves
               for f in missing)


def test_r1_flags_planted_async_cache_copy(decode_prog):
    nb = max(decode_prog.cache_bytes)
    elems = nb // 4
    planted = _splice_into_entry(
        decode_prog,
        f"%cs.999 = (f32[{elems}]{{0}}, f32[{elems}]{{0}}, u32[]) "
        "copy-start(%nothing)")
    findings = DonationAliasRule().check(planted)
    assert any(f.detail.get("bytes") == nb and "copy-start" in
               f.detail.get("line", "") for f in findings)


def test_r1_virtual_cache_tripwire_and_kernel_clean():
    """PR8 extension: R1 proves the Pallas kernel program never touches a
    virtual-cache-sized buffer, and the detector provably fires — the
    reference gather path at the SAME pool geometry materializes the
    (B, NB*page_size, Hkv, hd) buffer as gathers (plus copies of it), the
    exact traffic the kernel removes."""
    from repro.analysis.donation import virtual_cache_traffic
    kern = programs_lib.trace_program("paged_kernel", ARCH)
    assert virtual_cache_traffic(kern) == []
    assert DonationAliasRule().check(kern) == []

    gather = programs_lib.trace_program(
        "paged", ARCH,
        ecfg_kw=dict(page_size=kern.ecfg.page_size,
                     num_pages=kern.ecfg.num_pages))
    traffic = virtual_cache_traffic(gather)
    assert any(kind == "gather" for kind, _, _ in traffic)
    # the gather variant itself is NOT linted for virtual-cache traffic
    # (paged_kernel=False) — it stays the legal reference path
    assert DonationAliasRule().check(gather) == []


# ---------------------------------------------------------------------------
# R2 collective-bytes


def test_r2_clean_on_single_device(decode_prog):
    assert CollectiveBudgetRule().check(decode_prog) == []


def test_r2_flags_planted_collective(decode_prog):
    planted = _splice_into_entry(
        decode_prog,
        "%pl.999 = f32[4,4096]{1,0} all-reduce(%nothing), replica_groups={}")
    findings = CollectiveBudgetRule().check(planted)
    assert [f.detail["kind"] for f in findings] == ["all-reduce"]
    assert findings[0].severity == "error"
    assert findings[0].detail["actual"] == 4 * 4096 * 4


def test_predicted_collective_bytes_schedules():
    cfg = get_config(ARCH).reduced()
    iz, d, L = 4, cfg.d_model, cfg.num_layers
    t_bs = 2 * 4 // 2                    # batch=2, seq=4, 2 batch shards
    kw = dict(batch=2, seq=4, n_exp_shards=4, n_batch_shards=2)
    assert perf_model.predicted_collective_bytes(cfg, batch=2, seq=4) == {}
    dec = perf_model.predicted_collective_bytes(cfg, include_tp=False, **kw)
    assert dec == {"all-reduce": float(L * t_bs * d * iz)}
    cen = perf_model.predicted_collective_bytes(
        cfg.replace(expert_parallel="centralized"), include_tp=False, **kw)
    assert cen["reduce-scatter"] == float(L * t_bs * d * iz)
    assert cen["all-gather"] == float(L * (t_bs // 4) * (d * iz + 1))
    a2a = perf_model.predicted_collective_bytes(
        cfg.replace(expert_parallel="a2a"), include_tp=False, **kw)
    assert set(a2a) == {"all-to-all"} and a2a["all-to-all"] > 0
    # decode (seq=1): centralized falls back to psum + ring permute
    cen1 = perf_model.predicted_collective_bytes(
        cfg.replace(expert_parallel="centralized"), batch=2, seq=1,
        n_exp_shards=4, n_batch_shards=2, include_tp=False)
    assert cen1["all-reduce"] == cen1["collective-permute"] > 0


def test_predicted_collective_bytes_tp_terms():
    cfg = get_config(ARCH).reduced()
    iz, d, L = 4, cfg.d_model, cfg.num_layers
    t_bs = 2 * 4 // 2
    kw = dict(batch=2, seq=4, n_exp_shards=4, n_batch_shards=2)
    base = perf_model.predicted_collective_bytes(cfg, include_tp=False, **kw)
    tp = perf_model.predicted_collective_bytes(cfg, **kw)
    extra = t_bs * d * iz                          # vocab-sharded embedding
    if cfg.num_heads % 4 == 0:
        extra += L * t_bs * d * iz                 # per-layer wo psum
    assert tp["all-reduce"] == base["all-reduce"] + extra
    kv_flat = cfg.num_kv_heads * cfg.head_dim
    if cfg.num_kv_heads % 4 and kv_flat % 4 == 0:
        assert tp["all-gather"] == float(
            2 * L * t_bs * (kv_flat // 4) * iz)


# ---------------------------------------------------------------------------
# R3 retrace


def test_r3_clean_then_flags_undocumented_width():
    eng = programs_lib.build_engine("unified", ARCH)
    rule = RetraceRule()
    assert rule.check_engine(eng) == []          # documented set only
    assert expected_trace_budget(eng) == {"unified": 2}
    # a ragged chunk width (neither chunk_len nor 1) forces a retrace
    b = eng.ecfg.max_batch
    ivec = jnp.zeros((b,), jnp.int32)
    bvec = jnp.zeros((b,), bool)
    fvec = jnp.zeros((b,), jnp.float32)
    eng._jit_unified(eng.params, eng.cache, jnp.zeros((b, 3), jnp.int32),
                     ivec, ivec, ivec, None, bvec, bvec, fvec, ivec, fvec,
                     jnp.zeros((), jnp.int32), False)
    findings = RetraceRule(workload=None).check_engine(eng)
    assert [f.detail["body"] for f in findings] == ["unified"]
    assert findings[0].detail["count"] == 3


# ---------------------------------------------------------------------------
# R4 host-sync

_PLANTED_SOURCE = '''
class Fake:
    def step(self):
        out = self._jit_decode(self.params, self.cache)
        tok = out
        n = int(self.last_tok[0])
        v = tok.item()
        w = np.asarray(self.cache)
        if tok:
            pass
        self.cache.block_until_ready()
        if self.ecfg.async_steps > 0:
            self.cache.block_until_ready()

    def _harvest(self):
        return self.last_tok.item()
'''


def test_r4_clean_on_engine_source():
    findings = HostSyncRule().check_source()
    assert findings == [], [str(f) for f in findings]


def test_r4_flags_planted_syncs():
    findings = HostSyncRule().check_source(_PLANTED_SOURCE)
    whats = [f.detail["what"] for f in findings]
    assert "int() on a device array" in whats
    assert ".item() on a device array" in whats
    assert "np.asarray() on a device array" in whats
    assert "implicit bool() of a device array in a branch test" in whats
    # exactly one unguarded block_until_ready — the async_steps-guarded
    # one is the documented sync point and must pass
    assert len([w for w in whats if "block_until_ready" in w]) == 1
    # _harvest is the allowed boundary and is never scanned
    assert all(f.detail["method"] == "step" for f in findings)


# ---------------------------------------------------------------------------
# R5 quant integrity


def _quant_weight(d=64, dout=48, block=32):
    w = jnp.linspace(-1.0, 1.0, d * dout).reshape(d, dout)
    q, s = quant.absmax_quantize(w, bits=8, block=block, axis=-2)
    return quant.QuantTensor(q, s, 8, block, d, "float32")


def _r5_keys(fn, *args):
    qt = args[-1]
    leaves = programs_lib.quant_leaf_map((args[0], qt))
    assert leaves and leaves[0].data_idx == 1
    found = []
    check_closed_jaxpr(jax.make_jaxpr(fn)(*args), leaves,
                       lambda key, kw: found.append(key))
    return found


def test_r5_clean_on_qdot():
    x = jnp.ones((4, 64))
    assert _r5_keys(lambda x, qt: quant.qdot("td,dk->tk", x, qt),
                    x, _quant_weight()) == []


def test_r5_flags_detached_scale():
    x = jnp.ones((4, 64))

    def bad(x, qt):
        return x @ qt.data.astype(jnp.float32)   # dequant without scale

    assert ("detached", 1) in _r5_keys(bad, x, _quant_weight())


def test_r5_flags_full_materialization_outside_qdot():
    x = jnp.ones((4, 64))

    def bad(x, qt):
        scale = jnp.repeat(qt.scale, qt.block, axis=-2)
        w = qt.data.astype(jnp.float32) * scale  # full dequantized weight
        w = w + 0.0                              # escapes the qdot chain
        return x @ w

    assert ("materialized", 1) in _r5_keys(bad, x, _quant_weight())


def test_r5_clean_on_real_int8_unified_program():
    eng = programs_lib.build_engine("int8", ARCH)
    leaves = programs_lib.quant_leaf_map(eng.params)
    assert leaves, "int8 engine must hold QuantTensor leaves"
    b = eng.ecfg.max_batch
    ivec = jnp.zeros((b,), jnp.int32)
    bvec = jnp.zeros((b,), bool)
    fvec = jnp.zeros((b,), jnp.float32)
    closed = jax.make_jaxpr(eng._unified, static_argnums=(13,))(
        eng.params, eng.cache, jnp.zeros((b, eng.chunk_len), jnp.int32),
        ivec, ivec, ivec, None, bvec, bvec, fvec, ivec, fvec,
        jnp.zeros((), jnp.int32), False)
    found = []
    check_closed_jaxpr(closed, leaves, lambda key, kw: found.append(key))
    assert found == []


# ---------------------------------------------------------------------------
# R6 sharding lint


def test_r6_clean_and_flags_planted_expert_gather(decode_prog):
    assert ShardingLintRule().check(decode_prog) == []
    thr = expert_gather_threshold(decode_prog)
    assert thr and thr > 0
    planted = _splice_into_entry(
        decode_prog,
        f"%eg.999 = f32[{thr // 4}]{{0}} all-gather(%nothing), "
        "dimensions={0}")
    findings = ShardingLintRule().check(planted)
    assert len(findings) == 1 and findings[0].detail["bytes"] >= thr


# ---------------------------------------------------------------------------
# CLI


def test_cli_end_to_end(tmp_path):
    out = tmp_path / "report.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--programs", "decode",
         "--rules", "R1,R2,R4,R6", "--json", str(out)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    rep = json.loads(out.read_text())
    assert rep["ok"] and rep["n_errors"] == 0
    assert rep["rules"] == ["R1", "R2", "R4", "R6"]
    assert rep["programs"] == ["decode"]

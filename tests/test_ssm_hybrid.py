"""SSM (mamba2 SSD) and hybrid (RG-LRU) layer-level oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import mamba2, rglru


@pytest.fixture(scope="module")
def ssm_setup():
    cfg = get_config("mamba2_130m").reduced()
    key = jax.random.PRNGKey(0)
    p = mamba2.mamba_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg.d_model))
    return cfg, p, x


def test_ssd_chunk_size_invariance(ssm_setup):
    """The chunked SSD dual form must not depend on the chunk size — the
    state-space recurrence is exact for any blocking."""
    cfg, p, x = ssm_setup
    outs = [np.asarray(mamba2.mamba_forward(p, cfg, x, chunk=c))
            for c in (4, 8, 16, 32)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-4, atol=2e-5)


def test_ssd_forward_matches_stepwise_decode(ssm_setup):
    """Full-sequence SSD == token-by-token recurrent decode (duality)."""
    cfg, p, x = ssm_setup
    b, s, d = x.shape
    y_full, state_full = mamba2.mamba_forward(p, cfg, x, state={}, chunk=8)

    cache = jax.tree.map(lambda sp: jnp.zeros(sp.shape, sp.dtype),
                         mamba2.mamba_cache_spec(cfg, b, jnp.float32))
    ys = []
    for t in range(s):
        y_t, cache = mamba2.mamba_decode_step(p, cfg, cache, x[:, t:t + 1])
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(cache["ssm"]),
                               np.asarray(state_full["ssm"]),
                               rtol=2e-3, atol=2e-4)


def test_rglru_forward_matches_stepwise_decode():
    cfg = get_config("recurrentgemma_2b").reduced()
    key = jax.random.PRNGKey(2)
    p = rglru.rglru_init(key, cfg, jnp.float32)
    b, s = 2, 24
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, cfg.d_model))
    y_full, state_full = rglru.rglru_forward(p, cfg, x, state={})

    cache = jax.tree.map(lambda sp: jnp.zeros(sp.shape, sp.dtype),
                         rglru.rglru_cache_spec(cfg, b, jnp.float32))
    ys = []
    for t in range(s):
        y_t, cache = rglru.rglru_decode_step(p, cfg, cache, x[:, t:t + 1])
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=2e-3, atol=2e-4)


def test_rglru_gradients_finite():
    cfg = get_config("recurrentgemma_2b").reduced()
    key = jax.random.PRNGKey(3)
    p = rglru.rglru_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    g = jax.grad(lambda pp: jnp.sum(rglru.rglru_forward(pp, cfg, x) ** 2))(p)
    assert all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(g))


def test_ssd_gradients_finite(ssm_setup):
    cfg, p, x = ssm_setup
    g = jax.grad(lambda pp: jnp.sum(
        mamba2.mamba_forward(pp, cfg, x, chunk=8) ** 2))(p)
    assert all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(g))

"""Substrate: optimizer, data pipeline, checkpointing, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.ckpt import io as ckpt_io
from repro.configs.base import get_config
from repro.core import prestack
from repro.data.pipeline import (MemmapSource, Pipeline, PipelineConfig,
                                 SyntheticSource)
from repro.models.model import build_model
from repro.serving.engine import EngineConfig, ServingEngine


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_lr_schedule_warmup_and_cosine():
    cfg = optim.OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110,
                                min_lr_ratio=0.1)
    assert float(optim.lr_at(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(optim.lr_at(cfg, jnp.asarray(5))) - 0.5) < 1e-6
    assert abs(float(optim.lr_at(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    end = float(optim.lr_at(cfg, jnp.asarray(110)))
    assert abs(end - 0.1) < 1e-6


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-5
    assert abs(float(optim.adamw.global_norm(clipped)) - 1.0) < 1e-5


def test_adamw_converges_on_quadratic():
    cfg = optim.OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200,
                                weight_decay=0.0, clip_norm=1e9)
    params = {"x": jnp.asarray([5.0, -3.0])}
    st = optim.init(params)
    f = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(200):
        g = jax.grad(f)(params)
        params, st, _ = optim.update(cfg, g, st, params)
    assert float(f(params)) < 1e-2


def test_adamw_weight_decay_shrinks():
    cfg = optim.OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=100,
                                weight_decay=1.0, clip_norm=1e9)
    params = {"x": jnp.asarray([1.0])}
    st = optim.init(params)
    g = {"x": jnp.asarray([0.0])}
    p2, _, _ = optim.update(cfg, g, st, params)
    assert float(p2["x"][0]) < 1.0


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_shapes_and_labels():
    pc = PipelineConfig(seq_len=64, global_batch=8, vocab_size=100)
    pipe = Pipeline(pc)
    b = pipe.next_batch()
    assert b["tokens"].shape == (8, 64)
    assert b["labels"].shape == (8, 64)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert b["tokens"].max() < 100


def test_pipeline_deterministic():
    pc = PipelineConfig(seq_len=16, global_batch=2, vocab_size=50, seed=7)
    b1 = Pipeline(pc).next_batch()
    b2 = Pipeline(pc).next_batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_memmap_source(tmp_path):
    path = tmp_path / "toks.bin"
    data = np.arange(10_000, dtype=np.uint16) % 97
    data.tofile(path)
    src = MemmapSource(str(path))
    pc = PipelineConfig(seq_len=32, global_batch=4, vocab_size=97)
    pipe = Pipeline(pc, source=src)
    b = pipe.next_batch()
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].max() < 97


# ---------------------------------------------------------------------------
# checkpointing + prestack converter
# ---------------------------------------------------------------------------

def test_ckpt_roundtrip(tmp_path):
    cfg = get_config("qwen3_0_6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "ck.npz")
    ckpt_io.save(path, params, step=17)
    restored, step = ckpt_io.restore(path)
    assert step == 17
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_convert_unstacked_moe():
    """The paper's one-time stacking script: per-layer/per-expert checkpoint
    -> canonical prestacked layout, with granite-style padding."""
    L, E, D, F = 3, 5, 4, 8
    key = jax.random.PRNGKey(1)
    unstacked = {}
    for i in range(L):
        layer = {"ln": jnp.ones((D,))}
        for e in range(E):
            k = jax.random.fold_in(key, i * 100 + e)
            layer[f"expert_{e}"] = {
                "w_gate": jax.random.normal(k, (D, F))}
        unstacked[f"layer_{i}"] = layer
    stacked = ckpt_io.convert_unstacked(unstacked, num_experts_padded=8)
    assert stacked["experts"]["w_gate"].shape == (L, 8, D, F)
    assert stacked["ln"].shape == (L, D)
    # padded experts are zero
    assert float(jnp.sum(jnp.abs(stacked["experts"]["w_gate"][:, 5:]))) == 0.0
    # original weights preserved
    np.testing.assert_array_equal(
        np.asarray(stacked["experts"]["w_gate"][1, 2]),
        np.asarray(unstacked["layer_1"]["expert_2"]["w_gate"]))
    # inverse
    un2 = ckpt_io.to_unstacked(stacked, L)
    assert set(un2) == {f"layer_{i}" for i in range(L)}


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def moe_engine():
    cfg = get_config("qwen3_moe_30b_a3b").reduced()
    return ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                           max_cache=32))


def test_engine_completes_requests(moe_engine):
    rng = np.random.default_rng(0)
    uids = [moe_engine.submit(rng.integers(0, 100, 6), max_new_tokens=4)
            for _ in range(3)]
    done = moe_engine.run_until_done()
    assert sorted(r.uid for r in done) == sorted(uids)
    for r in done:
        assert len(r.generated) == 4
        assert all(0 <= t < moe_engine.cfg.vocab_size for t in r.generated)


def test_engine_tracker_statistic(moe_engine):
    e2 = moe_engine.expected_experts_per_node(2)
    assert 0.0 < e2 <= moe_engine.cfg.num_experts / 2 + 1e-9


def test_engine_standby_touches_experts(moe_engine):
    val = moe_engine.standby()
    assert np.isfinite(float(val))


def test_engine_dense_arch_no_tracker():
    cfg = get_config("qwen3_0_6b").reduced()
    eng = ServingEngine(cfg, EngineConfig(max_batch=1, prefill_len=8,
                                          max_cache=16))
    eng.submit(np.arange(4), max_new_tokens=2)
    done = eng.run_until_done()
    assert len(done) == 1 and len(done[0].generated) == 2

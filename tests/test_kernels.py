"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.moe_gemm import moe_ffn_kernel


def rand(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.1).astype(dtype)


@pytest.mark.parametrize("e,c,d,f", [
    (1, 8, 64, 128),
    (4, 16, 128, 256),
    (8, 128, 128, 64),
    (3, 33, 96, 80),        # ragged: exercises padding paths
    (2, 1, 128, 256),       # single-token decode capacity
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gemm_matches_ref(e, c, d, f, dtype):
    key = jax.random.PRNGKey(e * 1000 + c)
    ks = jax.random.split(key, 4)
    x = rand(ks[0], (e, c, d), dtype)
    wg = rand(ks[1], (e, d, f), dtype)
    wu = rand(ks[2], (e, d, f), dtype)
    wd = rand(ks[3], (e, f, d), dtype)
    y_k = moe_ffn_kernel(x, wg, wu, wd, interpret=True)
    y_r = ref.moe_ffn_ref(x, wg, wu, wd)
    assert y_k.shape == y_r.shape == (e, c, d)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bc,bf", [(32, 64), (128, 256), (8, 16)])
def test_moe_gemm_block_shape_invariance(bc, bf):
    """Output must not depend on the BlockSpec tiling."""
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 4)
    e, c, d, f = 2, 64, 128, 128
    x = rand(ks[0], (e, c, d), jnp.float32)
    wg = rand(ks[1], (e, d, f), jnp.float32)
    wu = rand(ks[2], (e, d, f), jnp.float32)
    wd = rand(ks[3], (e, f, d), jnp.float32)
    y = moe_ffn_kernel(x, wg, wu, wd, block_c=bc, block_f=bf, interpret=True)
    y_r = ref.moe_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=1e-5, atol=1e-5)


def test_ops_wrapper_dispatches_interpret_on_cpu():
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 4)
    e, c, d, f = 2, 16, 64, 64
    x = rand(ks[0], (e, c, d), jnp.float32)
    wg = rand(ks[1], (e, d, f), jnp.float32)
    wu = rand(ks[2], (e, d, f), jnp.float32)
    wd = rand(ks[3], (e, f, d), jnp.float32)
    y = ops.moe_ffn(x, wg, wu, wd)
    y_r = ref.moe_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=1e-5, atol=1e-5)


def test_kernel_zero_padding_exactness():
    """Zero rows (dispatch padding slots) must produce exactly zero output."""
    e, c, d, f = 2, 16, 64, 64
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    x = jnp.zeros((e, c, d), jnp.float32)
    wg = rand(ks[0], (e, d, f), jnp.float32)
    wu = rand(ks[1], (e, d, f), jnp.float32)
    wd = rand(ks[2], (e, f, d), jnp.float32)
    y = moe_ffn_kernel(x, wg, wu, wd, interpret=True)
    assert float(jnp.max(jnp.abs(y))) == 0.0


# ---------------------------------------------------------------------------
# quantized grouped GEMM (in-kernel dequant of blockwise int8/int4 weights)
# ---------------------------------------------------------------------------

from repro.core import quant
from repro.kernels.moe_gemm import moe_ffn_kernel_quant


@pytest.mark.parametrize("e,c,d,f", [
    (1, 8, 64, 128),
    (4, 16, 128, 256),
    (3, 33, 96, 80),        # ragged C/F: padding paths + F < quant block
    (2, 1, 128, 256),       # single-token decode capacity
])
@pytest.mark.parametrize("level", ["int8", "int4"])
@pytest.mark.parametrize("qb", [64, 128])
def test_moe_gemm_quant_matches_ref(e, c, d, f, level, qb):
    """The in-VMEM tile dequant (ISSUE 5 tentpole) must match the
    dequantize-then-dense oracle across shapes, bit widths and quant
    blocks — including F that none of (tile, quant block) divides."""
    key = jax.random.PRNGKey(e * 1000 + c + qb)
    ks = jax.random.split(key, 4)
    x = rand(ks[0], (e, c, d), jnp.float32)
    wg = quant.quantize(rand(ks[1], (e, d, f), jnp.float32), level, block=qb)
    wu = quant.quantize(rand(ks[2], (e, d, f), jnp.float32), level, block=qb)
    wd = quant.quantize(rand(ks[3], (e, f, d), jnp.float32), level, block=qb)
    y = moe_ffn_kernel_quant(x, wg, wu, wd, interpret=True)
    y_r = ref.moe_ffn_ref_quant(x, wg, wu, wd)
    assert y.shape == y_r.shape == (e, c, d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bc,bf", [(32, 64), (128, 256), (8, 16)])
def test_moe_gemm_quant_block_shape_invariance(bc, bf):
    """Output must not depend on the BlockSpec tiling (the f-tile is
    clamped to whole quant blocks internally)."""
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 4)
    e, c, d, f = 2, 64, 128, 128
    x = rand(ks[0], (e, c, d), jnp.float32)
    wg = quant.quantize(rand(ks[1], (e, d, f), jnp.float32), "int8",
                        block=64)
    wu = quant.quantize(rand(ks[2], (e, d, f), jnp.float32), "int8",
                        block=64)
    wd = quant.quantize(rand(ks[3], (e, f, d), jnp.float32), "int8",
                        block=64)
    y = moe_ffn_kernel_quant(x, wg, wu, wd, block_c=bc, block_f=bf,
                             interpret=True)
    y_r = ref.moe_ffn_ref_quant(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=1e-5, atol=1e-5)


def test_moe_gemm_quant_bf16_activations():
    """bf16 activations over a quantized store (the production dtype mix)
    stay within the bf16 kernel tolerance of the oracle."""
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, 4)
    e, c, d, f = 2, 16, 128, 256
    x = rand(ks[0], (e, c, d), jnp.bfloat16)
    mk = lambda k, s: quant.quantize(rand(k, s, jnp.bfloat16), "int8",
                                     block=64)
    wg, wu, wd = mk(ks[1], (e, d, f)), mk(ks[2], (e, d, f)), \
        mk(ks[3], (e, f, d))
    y = moe_ffn_kernel_quant(x, wg, wu, wd, interpret=True)
    y_r = ref.moe_ffn_ref_quant(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_ops_wrapper_dispatches_quantized():
    """ops.moe_ffn routes QuantTensor weights to the quantized kernel —
    the expert_ffn(use_kernel=True) path needs no call-site branching."""
    key = jax.random.PRNGKey(13)
    ks = jax.random.split(key, 4)
    e, c, d, f = 2, 16, 64, 64
    x = rand(ks[0], (e, c, d), jnp.float32)
    wg = quant.quantize(rand(ks[1], (e, d, f), jnp.float32), "int4",
                        block=32)
    wu = quant.quantize(rand(ks[2], (e, d, f), jnp.float32), "int4",
                        block=32)
    wd = quant.quantize(rand(ks[3], (e, f, d), jnp.float32), "int4",
                        block=32)
    y = ops.moe_ffn(x, wg, wu, wd)
    y_r = ref.moe_ffn_ref_quant(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

from repro.kernels.flash_attn import flash_attention


@pytest.mark.parametrize("s,window,causal", [
    (64, None, True), (128, 32, True), (96, None, True),
    (64, None, False), (80, 48, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(s, window, causal, dtype):
    key = jax.random.PRNGKey(s)
    b, h, hd = 2, 3, 64
    ks = jax.random.split(key, 3)
    q = rand(ks[0], (b, h, s, hd), dtype)
    k = rand(ks[1], (b, h, s, hd), dtype)
    v = rand(ks[2], (b, h, s, hd), dtype)
    y = flash_attention(q, k, v, causal=causal, window=window,
                        block_q=32, block_k=32, interpret=True)
    y_r = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bq,bk", [(16, 32), (64, 64), (32, 16)])
def test_flash_attention_block_invariance(bq, bk):
    key = jax.random.PRNGKey(9)
    b, h, s, hd = 1, 2, 128, 32
    ks = jax.random.split(key, 3)
    q = rand(ks[0], (b, h, s, hd), jnp.float32)
    k = rand(ks[1], (b, h, s, hd), jnp.float32)
    v = rand(ks[2], (b, h, s, hd), jnp.float32)
    y = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    y_r = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged attention kernel (block-table decode + chunked prefill)
# ---------------------------------------------------------------------------

from repro.kernels.paged_attn import paged_attention


def _paged_case(seed, *, b, t, hq, hkv, hd, ps, nb, num_pages, quant=False,
                dtype=jnp.float32):
    """Random pool + per-row block tables (distinct pages per row) with
    lengths spread over the table's reach and ragged seg_lens."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, t, hq, hd)) * 0.1, dtype)
    bt = jnp.asarray(rng.permuted(
        np.tile(np.arange(num_pages), (b, 1)), axis=1)[:, :nb], jnp.int32)
    lengths = jnp.asarray(rng.integers(0, max(nb * ps - t, 1), b), jnp.int32)
    seg = jnp.asarray(rng.integers(0, t + 1, b), jnp.int32)
    shape = (num_pages, ps, hkv, hd)
    if quant:
        kp = jnp.asarray(rng.integers(-127, 128, shape), jnp.int8)
        vp = jnp.asarray(rng.integers(-127, 128, shape), jnp.int8)
        # spread scales over two orders of magnitude: the in-kernel
        # dequant must track per-(page, slot, head) scale exactly
        ks = jnp.asarray(10 ** rng.uniform(-3, -1, shape[:-1] + (1,)),
                         jnp.float32)
        vs = jnp.asarray(10 ** rng.uniform(-3, -1, shape[:-1] + (1,)),
                         jnp.float32)
    else:
        kp = jnp.asarray(rng.standard_normal(shape) * 0.1, dtype)
        vp = jnp.asarray(rng.standard_normal(shape) * 0.1, dtype)
        ks = vs = None
    return q, kp, vp, bt, lengths, seg, ks, vs


@pytest.mark.parametrize("t,hq,hkv,ps,nb,window", [
    (1, 4, 2, 8, 4, None),       # decode, G=2
    (1, 8, 2, 16, 2, None),      # decode, G=4
    (4, 4, 2, 5, 7, None),       # chunked prefill, page_size divides nothing
    (6, 8, 2, 3, 9, 4),          # windowed prefill, ragged everything
    (7, 6, 6, 4, 6, None),       # MHA (G=1), t*G not a block multiple
    (3, 4, 1, 5, 5, 7),          # single kv head, window wider than a page
])
@pytest.mark.parametrize("quant", [False, True])
def test_paged_attention_matches_ref(t, hq, hkv, ps, nb, window, quant):
    """Kernel vs page-walk oracle across ragged page sizes, GQA group
    counts, window/non-window, fp and int8-with-scales pools."""
    q, kp, vp, bt, ln, sg, ks, vs = _paged_case(
        t * 100 + hq * 10 + ps, b=3, t=t, hq=hq, hkv=hkv, hd=32, ps=ps,
        nb=nb, num_pages=nb + 3, quant=quant)
    y = paged_attention(q, kp, vp, bt, ln, sg, k_scale=ks, v_scale=vs,
                        window=window, interpret=True)
    y_r = ref.paged_attention_ref(q, kp, vp, bt, ln, sg, k_scale=ks,
                                  v_scale=vs, window=window)
    assert y.shape == y_r.shape == q.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bq", [1, 2, 8, 128])
def test_paged_attention_block_q_invariance(bq):
    """Output must not depend on the q-row tiling."""
    q, kp, vp, bt, ln, sg, ks, vs = _paged_case(
        11, b=2, t=5, hq=4, hkv=2, hd=32, ps=4, nb=6, num_pages=9)
    y = paged_attention(q, kp, vp, bt, ln, sg, block_q=bq, interpret=True)
    y_r = ref.paged_attention_ref(q, kp, vp, bt, ln, sg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_padding_rows_zero():
    """Tokens past seg_lens are padding: their output rows must be exactly
    zero (finite garbage would still be ignored by the engine's last-valid
    logit selection, but zero is the kernel's contract)."""
    q, kp, vp, bt, ln, sg, *_ = _paged_case(
        5, b=2, t=6, hq=4, hkv=2, hd=32, ps=4, nb=6, num_pages=8)
    sg = jnp.asarray([2, 0], jnp.int32)
    y = paged_attention(q, kp, vp, bt, ln, sg, interpret=True)
    assert float(jnp.max(jnp.abs(y[0, 2:]))) == 0.0
    assert float(jnp.max(jnp.abs(y[1]))) == 0.0


def test_paged_attention_garbage_pages_masked():
    """Pool pages outside every row's block-table reach hold NaN/Inf
    garbage; table entries past a row's live extent point at page 0.  The
    position mask (and the OOB write sentinel upstream) must keep all of
    it out of the output."""
    q, kp, vp, bt, ln, sg, *_ = _paged_case(
        7, b=2, t=3, hq=4, hkv=2, hd=32, ps=4, nb=4, num_pages=8)
    used = np.unique(np.asarray(bt))
    garbage = np.setdiff1d(np.arange(8), used)
    kp = kp.at[garbage].set(jnp.nan)
    vp = vp.at[garbage].set(jnp.inf)
    y = paged_attention(q, kp, vp, bt, ln, sg, interpret=True)
    y_r = ref.paged_attention_ref(q, kp, vp, bt, ln, sg)
    assert bool(jnp.all(jnp.isfinite(y)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=2e-5, atol=2e-5)


def test_ops_paged_attention_dispatches_cache_dict():
    """ops.paged_attention unpacks the pool cache dict and routes int8
    pools (sibling scale leaves) to the in-kernel-dequant variant."""
    q, kp, vp, bt, ln, sg, ks, vs = _paged_case(
        3, b=2, t=1, hq=4, hkv=2, hd=32, ps=8, nb=3, num_pages=5,
        quant=True)
    y = ops.paged_attention(q, {"k": kp, "v": vp, "k_scale": ks,
                                "v_scale": vs}, bt, ln, sg)
    y_r = ref.paged_attention_ref(q, kp, vp, bt, ln, sg, k_scale=ks,
                                  v_scale=vs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=2e-5, atol=2e-5)


def test_model_level_flash_kernel_equivalence():
    """cfg.use_flash_kernel routes attention through the Pallas kernel
    (interpret mode on CPU) and must match the standard path end-to-end."""
    from repro.configs.base import get_config
    from repro.models.model import build_model
    cfg = get_config("qwen3_0_6b").reduced()
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                   jnp.int32)}
    m0 = build_model(cfg)
    m1 = build_model(cfg.replace(use_flash_kernel=True))
    params = m0.init(jax.random.PRNGKey(0))
    l0, _ = m0.forward(params, batch)
    l1, _ = m1.forward(params, batch)
    np.testing.assert_allclose(np.asarray(l0, np.float32),
                               np.asarray(l1, np.float32),
                               rtol=2e-4, atol=2e-4)

"""Per-architecture smoke tests on reduced configs (CPU, 1 device):
forward/train step runs, output shapes correct, no NaNs, and the cached
prefill+decode path agrees with the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models.model import build_model


def make_batch(cfg, b, s, rng):
    if cfg.family == "audio":
        return {"frame_embeds": jnp.asarray(
                    rng.normal(size=(b, s, cfg.d_model)), jnp.float32),
                "labels": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.family == "vlm":
        p = cfg.num_patch_tokens
        return {"tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (b, s - p)), jnp.int32),
                "patch_embeds": jnp.asarray(
                    rng.normal(size=(b, p, cfg.d_model)), jnp.float32),
                "mrope_positions": jnp.broadcast_to(
                    jnp.arange(s, dtype=jnp.int32)[None, :, None], (b, s, 3)),
                "labels": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    return {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = 2, 16
    batch = make_batch(cfg, b, s, rng)

    logits, aux = model.forward(params, batch)
    assert logits.shape == (b, s, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, metrics = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))

    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """decode(prefill(x[:-1]), x[-1]) logits == forward(x) last-token logits."""
    cfg = get_config(arch).reduced()
    if cfg.is_moe:
        # high capacity so the full-sequence and single-token paths drop the
        # same (zero) tokens; capacity effects are tested in test_core_moe
        cfg = cfg.replace(capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    b, s = 2, 12
    batch = make_batch(cfg, b, s, rng)
    batch.pop("labels")

    full_logits, _ = model.forward(params, batch)          # (b, s, V)

    # prefill on the first s-1 tokens, then decode token s-1
    def cut(v):
        return v[:, :s - 1] if v.ndim >= 2 and v.shape[1] in (s, s - cfg.num_patch_tokens) else v

    if cfg.family == "audio":
        pre = {"frame_embeds": batch["frame_embeds"][:, :s - 1]}
        step_tok = jnp.zeros((b, 1), jnp.int32)  # decode embeds tokens; skip
        pytest.skip("audio decode consumes token ids (EnCodec): covered by "
                    "test_decode_runs below")
    elif cfg.family == "vlm":
        pre = {"tokens": batch["tokens"][:, :-1],
               "patch_embeds": batch["patch_embeds"],
               "mrope_positions": batch["mrope_positions"][:, :s - 1]}
        step_tok = batch["tokens"][:, -1:]
    else:
        pre = {"tokens": batch["tokens"][:, :s - 1]}
        step_tok = batch["tokens"][:, -1:]

    cache = model.init_cache(b, s + 4)
    _, cache = model.prefill(params, pre, cache)
    step = {"tokens": step_tok, "lengths": jnp.full((b,), s - 1, jnp.int32)}
    if cfg.family == "vlm":
        step["mrope_positions"] = batch["mrope_positions"][:, -1:]
    dec_logits, _ = model.decode_step(params, cache, step)

    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32),
        rtol=2e-2, atol=2e-2)


def test_audio_decode_runs():
    cfg = get_config("musicgen_large").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = 2
    cache = model.init_cache(b, 16)
    pre = {"frame_embeds": jnp.ones((b, 8, cfg.d_model), jnp.float32)}
    logits, cache = model.prefill(params, pre, cache)
    step = {"tokens": jnp.zeros((b, 1), jnp.int32),
            "lengths": jnp.full((b,), 8, jnp.int32)}
    logits, cache = model.decode_step(params, cache, step)
    assert logits.shape[0] == b and bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["mamba2_130m", "recurrentgemma_2b"])
def test_long_context_decode_state_is_bounded(arch):
    """SSM/hybrid long_500k viability: cache size independent of context."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    short = model.cache_specs(2, 64)
    long = model.cache_specs(2, 4096)
    short_b = sum(np.prod(s.shape) for s in jax.tree.leaves(short))
    long_b = sum(np.prod(s.shape) for s in jax.tree.leaves(long))
    if cfg.family == "ssm":
        assert short_b == long_b
    else:  # hybrid: attention window bounded by sliding_window
        assert long_b <= short_b * (cfg.sliding_window * 2 / 64)


def test_sliding_window_variant_bounds_dense_cache():
    """Dense archs switch to SWA beyond the long-context threshold."""
    cfg = get_config("qwen2_72b")
    model = build_model(cfg)
    spec = model.cache_specs(1, 524_288)
    assert spec["k"].shape[2] == cfg.long_context_window


@pytest.mark.parametrize("arch", ["qwen3_moe_30b_a3b", "granite_moe_3b_a800m"])
def test_moe_strategies_agree(arch):
    """dense (L_B) and dispatch (L_R) strategies produce the same model
    output at high capacity — the paper's methods differ in cost, not math."""
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(2)
    b, s = 2, 8
    batch = make_batch(cfg, b, s, rng)

    outs = {}
    for strat in ("dense", "dispatch"):
        c = cfg.replace(moe_strategy=strat, capacity_factor=8.0)
        model = build_model(c)
        params = model.init(jax.random.PRNGKey(3))
        logits, _ = model.forward(params, batch)
        outs[strat] = np.asarray(logits, np.float32)
    np.testing.assert_allclose(outs["dense"], outs["dispatch"],
                               rtol=2e-3, atol=2e-3)


def test_prestack_vs_unstacked_forward():
    """prestack=False (the paper's naive 'unstacking' baseline) is
    numerically identical to the canonical prestacked path."""
    cfg = get_config("qwen3_0_6b").reduced()
    rng = np.random.default_rng(4)
    batch = make_batch(cfg, 2, 8, rng)
    m1 = build_model(cfg.replace(prestack=True))
    m2 = build_model(cfg.replace(prestack=False))
    p = m1.init(jax.random.PRNGKey(5))
    l1, _ = m1.forward(p, batch)
    l2, _ = m2.forward(p, batch)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), rtol=1e-5, atol=1e-5)

"""Multi-device integration checks, run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (tests/test_distributed.py
drives this; the pytest main process keeps the single real CPU device).

Each check prints 'PASS <name>' or raises.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import expert_parallel, moe as moe_lib, router as router_lib
from repro.launch import sharding
from repro.launch.mesh import make_test_mesh
from repro.models import attention
from repro.models.model import build_model
from repro import optim


def check_expert_parallel_schedules():
    """All 3 collective schedules x 2 strategies match the exact reference."""
    mesh = make_test_mesh(2, 4)
    cfg = get_config("qwen3_moe_30b_a3b").reduced()
    key = jax.random.PRNGKey(0)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts_padded
    layer_p = {
        "router": jax.random.normal(key, (d, e)) * 0.1,
        "experts": {
            "w_gate": jax.random.normal(jax.random.fold_in(key, 1), (e, d, f)) * 0.05,
            "w_up": jax.random.normal(jax.random.fold_in(key, 2), (e, d, f)) * 0.05,
            "w_down": jax.random.normal(jax.random.fold_in(key, 3), (e, f, d)) * 0.05,
        },
    }
    for b, s in ((4, 16), (4, 1)):
        x = jax.random.normal(jax.random.fold_in(key, 4 + s), (b, s, d))
        x2d = x.reshape(-1, d)
        rout = router_lib.route(layer_p["router"], x2d, cfg.experts_per_token,
                                n_valid_experts=cfg.num_experts)
        y_ref = moe_lib.reference_moe(layer_p["experts"], x2d, rout.top_idx,
                                      rout.top_w).reshape(b, s, d)
        for ep in ("decentralized", "centralized", "a2a", "a2a_pipelined"):
            for strat in ("dispatch", "dense"):
                # gather_decode_max_tk=0 keeps the dispatch path exercised
                # even at small T*K (the gather fast path is checked below)
                c = cfg.replace(expert_parallel=ep, moe_strategy=strat,
                                capacity_factor=8.0, ep_microchunks=2,
                                gather_decode_max_tk=0)
                y, aux, ti = expert_parallel.moe_layer(c, mesh, layer_p, x)
                err = float(jnp.max(jnp.abs(y - y_ref)))
                assert err < 1e-4, (ep, strat, s, err)
                assert np.isfinite(float(aux))
                # device-captured routing == single-device router decisions
                np.testing.assert_array_equal(np.asarray(ti),
                                              np.asarray(rout.top_idx))
        # capacity-free gather decode fast path on the mesh (T*K below the
        # threshold): same exact output through the decentralized schedule
        c = cfg.replace(expert_parallel="decentralized",
                        capacity_factor=8.0, gather_decode_max_tk=4096)
        y, _, _ = expert_parallel.moe_layer(c, mesh, layer_p, x)
        err = float(jnp.max(jnp.abs(y - y_ref)))
        assert err < 1e-4, ("gather", s, err)
    print("PASS expert_parallel_schedules")


def check_a2a_pipelined_token_exact():
    """ISSUE 2 tentpole (b): the microchunked comm/compute-overlapped
    schedule is token-exact against plain a2a whenever capacity is not
    binding — identical routing decisions and per-slot contractions; the
    outputs differ only by XLA's reduction-order reassociation at the
    different GEMM batch shapes (<1e-6 abs, which never flips a greedy
    token — asserted end-to-end in check_serving_engine_on_mesh).  a2a
    matches decentralized in the same regime, and the documented fallbacks
    engage (m that does not divide T_loc -> a2a; single-token decode ->
    decentralized), bitwise, since they run the same code."""
    mesh = make_test_mesh(2, 4)
    cfg = get_config("qwen3_moe_30b_a3b").reduced().replace(
        capacity_factor=8.0, gather_decode_max_tk=0)
    key = jax.random.PRNGKey(17)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts_padded
    layer_p = {
        "router": jax.random.normal(key, (d, e)) * 0.1,
        "experts": {
            "w_gate": jax.random.normal(jax.random.fold_in(key, 1), (e, d, f)) * 0.05,
            "w_up": jax.random.normal(jax.random.fold_in(key, 2), (e, d, f)) * 0.05,
            "w_down": jax.random.normal(jax.random.fold_in(key, 3), (e, f, d)) * 0.05,
        },
    }
    b, s = 4, 16                       # T_loc = (4/2)*(16/4) = 8 per shard
    x = jax.random.normal(jax.random.fold_in(key, 4), (b, s, d))
    y_a2a, _, ti_a2a = expert_parallel.moe_layer(
        cfg.replace(expert_parallel="a2a"), mesh, layer_p, x)
    y_dec, _, _ = expert_parallel.moe_layer(
        cfg.replace(expert_parallel="decentralized"), mesh, layer_p, x)
    for m in (2, 4, 8):
        c = cfg.replace(expert_parallel="a2a_pipelined", ep_microchunks=m)
        y_p, aux, ti = expert_parallel.moe_layer(c, mesh, layer_p, x)
        np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_a2a),
                                   rtol=0, atol=1e-5, err_msg=f"m={m}")
        np.testing.assert_array_equal(np.asarray(ti), np.asarray(ti_a2a))
        assert np.isfinite(float(aux))
    # a2a == decentralized token-exact under non-binding capacity
    err = float(jnp.max(jnp.abs(y_a2a - y_dec)))
    assert err < 1e-5, err
    # m=3 does not divide T_loc=8: falls back to plain a2a, still exact
    y_f, _, _ = expert_parallel.moe_layer(
        cfg.replace(expert_parallel="a2a_pipelined", ep_microchunks=3),
        mesh, layer_p, x)
    np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_a2a))
    # single-token decode: falls back to the decentralized schedule
    x1 = jax.random.normal(jax.random.fold_in(key, 5), (b, 1, d))
    y1_p, _, _ = expert_parallel.moe_layer(
        cfg.replace(expert_parallel="a2a_pipelined", ep_microchunks=2),
        mesh, layer_p, x1)
    y1_d, _, _ = expert_parallel.moe_layer(
        cfg.replace(expert_parallel="decentralized"), mesh, layer_p, x1)
    np.testing.assert_array_equal(np.asarray(y1_p), np.asarray(y1_d))
    print("PASS a2a_pipelined_token_exact")


def check_cp_decode_matches_single_device():
    """Sequence-sharded decode attention (shard_map online-softmax merge)
    equals the single-device decode step."""
    mesh = make_test_mesh(2, 4)
    cfg = get_config("qwen3_0_6b").reduced()
    key = jax.random.PRNGKey(1)
    p = attention.attn_init(key, cfg, jnp.float32)
    b, clen = 4, 32
    cache1 = attention.init_layer_cache(cfg, b, clen, jnp.float32)
    cache2 = {k: jnp.copy(v) for k, v in cache1.items()}
    # pre-populate with a short prefix
    for t in range(5):
        x = jax.random.normal(jax.random.fold_in(key, 10 + t),
                              (b, 1, cfg.d_model))
        lengths = jnp.full((b,), t, jnp.int32)
        o1, cache1 = attention.attn_decode_step(p, cfg, cache1, x, lengths,
                                                None)
        o2, cache2 = attention.attn_decode_step_cp(p, cfg, cache2, x, lengths,
                                                   None, mesh)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-4, atol=1e-5)
        for kk in ("k", "v"):
            np.testing.assert_allclose(np.asarray(cache1[kk]),
                                       np.asarray(cache2[kk]),
                                       rtol=1e-5, atol=1e-6)
    print("PASS cp_decode")


def check_cp_decode_ring_window():
    """CP decode with a ring (sliding-window) cache matches the local path."""
    mesh = make_test_mesh(1, 8)
    cfg = get_config("recurrentgemma_2b").reduced()
    key = jax.random.PRNGKey(2)
    p = attention.attn_init(key, cfg, jnp.float32)
    b, win = 2, cfg.sliding_window
    assert win % 8 == 0, win
    c1 = attention.init_layer_cache(cfg, b, win, jnp.float32)
    c2 = {k: jnp.copy(v) for k, v in c1.items()}
    for t in range(win + 9):        # wrap the ring
        x = jax.random.normal(jax.random.fold_in(key, t), (b, 1, cfg.d_model))
        lengths = jnp.full((b,), t, jnp.int32)
        o1, c1 = attention.attn_decode_step(p, cfg, c1, x, lengths, win)
        o2, c2 = attention.attn_decode_step_cp(p, cfg, c2, x, lengths, win,
                                               mesh)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-4, atol=1e-5, err_msg=f"t={t}")
    print("PASS cp_decode_ring")


def check_sharded_train_step_matches_single():
    """2 sharded train steps == 2 unsharded train steps (same loss curve)."""
    mesh = make_test_mesh(2, 4)
    cfg = get_config("qwen3_moe_30b_a3b").reduced().replace(
        capacity_factor=8.0)
    model = build_model(cfg)
    params0 = model.init(jax.random.PRNGKey(3))
    ocfg = optim.OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    key = jax.random.PRNGKey(4)
    b, s = 8, 16
    batches = [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                             (b, s), 0, cfg.vocab_size),
                "labels": jax.random.randint(jax.random.fold_in(key, 99 + i),
                                             (b, s), 0, cfg.vocab_size)}
               for i in range(2)]

    def run(mesh_):
        params = jax.tree.map(jnp.copy, params0)
        opt = optim.init(params)
        if mesh_ is not None:
            spec = sharding.params_pspec(cfg, mesh_, params, mode="train")
            params = jax.device_put(params, sharding.named(mesh_, spec))
            opt = jax.device_put(opt, sharding.named(
                mesh_, sharding.opt_pspec(cfg, mesh_, opt, spec)))

        @jax.jit
        def step(params, opt, batch):
            (l, m), g = jax.value_and_grad(model.loss, has_aux=True)(
                params, batch, mesh_)
            params, opt, _ = optim.update(ocfg, g, opt, params)
            return params, opt, l

        losses = []
        for bt in batches:
            params, opt, l = step(params, opt, bt)
            losses.append(float(l))
        return losses

    l_single = run(None)
    l_shard = run(mesh)
    np.testing.assert_allclose(l_single, l_shard, rtol=2e-3, atol=2e-3)
    print("PASS sharded_train_step")


def check_params_pspec_structure():
    """Sharding specs: experts on model axis; attention replicated when heads
    do not divide; vocab sharded."""
    from jax.sharding import PartitionSpec as P
    mesh = make_test_mesh(2, 4)
    cfg = get_config("qwen3_moe_30b_a3b")
    model = build_model(cfg)
    p_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    spec = sharding.params_pspec(cfg, mesh, p_sds, mode="serve")
    assert spec["embed"] == P("model", None)
    assert spec["blocks"]["experts"]["w_gate"] == P(None, "model", None, None)
    assert spec["blocks"]["attn"]["wq"][2] == "model"      # 32 heads % 4 == 0
    assert spec["blocks"]["attn"]["wk"][2] == "model"      # 4 kv % 4 == 0
    # vlm: 28 heads % 4 == 0 -> sharded; but % 16 on prod mesh is not:
    cfg_vlm = get_config("qwen2_vl_7b")
    m_vlm = build_model(cfg_vlm)
    sds = jax.eval_shape(m_vlm.init, jax.random.PRNGKey(0))
    sp = sharding.params_pspec(cfg_vlm, mesh, sds, mode="serve")
    assert sp["blocks"]["attn"]["wq"][2] == "model"        # 28 % 4 == 0 here
    print("PASS params_pspec_structure")


def check_data_sharded_batch():
    from repro.data.pipeline import Pipeline, PipelineConfig, shard_batch
    mesh = make_test_mesh(4, 2)
    pipe = Pipeline(PipelineConfig(seq_len=16, global_batch=8, vocab_size=64))
    b = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
    sb = shard_batch(b, mesh)
    assert sb["tokens"].sharding.spec[0] in ("data", ("data",))
    print("PASS data_sharded_batch")


def check_padded_experts_dead_on_mesh():
    """granite-style expert padding: 6 real experts padded to 8 so they
    divide a 4-way expert-parallel axis; padded experts carry zero weights
    and -inf router logits — output must equal the 6-expert reference."""
    mesh = make_test_mesh(2, 4)
    cfg = get_config("granite_moe_3b_a800m").reduced().replace(
        num_experts=6, num_experts_padded=8, experts_per_token=2,
        capacity_factor=8.0)
    key = jax.random.PRNGKey(7)
    d, f = cfg.d_model, cfg.d_ff
    real = {
        "w_gate": jax.random.normal(jax.random.fold_in(key, 1), (6, d, f)) * 0.05,
        "w_up": jax.random.normal(jax.random.fold_in(key, 2), (6, d, f)) * 0.05,
        "w_down": jax.random.normal(jax.random.fold_in(key, 3), (6, f, d)) * 0.05,
    }
    from repro.core import prestack
    layer_p = {
        "router": jnp.pad(jax.random.normal(key, (d, 6)) * 0.1,
                          ((0, 0), (0, 2))),
        "experts": prestack.pad_experts(real, 8),
    }
    x = jax.random.normal(jax.random.fold_in(key, 4), (4, 8, d))
    x2d = x.reshape(-1, d)
    rout = router_lib.route(layer_p["router"][:, :6], x2d,
                            cfg.experts_per_token)
    y_ref = moe_lib.reference_moe(real, x2d, rout.top_idx,
                                  rout.top_w).reshape(4, 8, d)
    for ep in ("decentralized", "centralized", "a2a", "a2a_pipelined"):
        c = cfg.replace(expert_parallel=ep, ep_microchunks=2)
        y, _, _ = expert_parallel.moe_layer(c, mesh, layer_p, x)
        err = float(jnp.max(jnp.abs(y - y_ref)))
        assert err < 1e-4, (ep, err)
    print("PASS padded_experts")


def check_expert_replication_overlap():
    """Paper §5.3 overlapping placement: r=2 replicas on an 8-way expert
    axis must produce the exact single-copy output (each token served by
    exactly one replica) while halving per-shard capacity."""
    mesh = make_test_mesh(1, 8)
    cfg = get_config("qwen3_moe_30b_a3b").reduced().replace(
        num_experts=8, num_experts_padded=8, experts_per_token=2,
        capacity_factor=8.0)
    key = jax.random.PRNGKey(11)
    d, f, e = cfg.d_model, cfg.d_ff, 8
    experts = {
        "w_gate": jax.random.normal(jax.random.fold_in(key, 1), (e, d, f)) * 0.05,
        "w_up": jax.random.normal(jax.random.fold_in(key, 2), (e, d, f)) * 0.05,
        "w_down": jax.random.normal(jax.random.fold_in(key, 3), (e, f, d)) * 0.05,
    }
    router_w = jax.random.normal(key, (d, e)) * 0.1
    x = jax.random.normal(jax.random.fold_in(key, 4), (2, 16, d))
    x2d = x.reshape(-1, d)
    rout = router_lib.route(router_w, x2d, cfg.experts_per_token)
    y_ref = moe_lib.reference_moe(experts, x2d, rout.top_idx,
                                  rout.top_w).reshape(2, 16, d)

    # r=1 baseline
    y1, _, _ = expert_parallel.moe_layer(
        cfg, mesh, {"router": router_w, "experts": experts}, x)
    # r=2 overlapping placement (duplicated expert stack)
    dup = jax.tree.map(lambda a: jnp.concatenate([a, a], axis=0), experts)
    y2, _, _ = expert_parallel.moe_layer(
        cfg.replace(expert_replication=2), mesh,
        {"router": router_w, "experts": dup}, x)
    for name, y in (("r1", y1), ("r2", y2)):
        err = float(jnp.max(jnp.abs(y - y_ref)))
        assert err < 1e-4, (name, err)
    print("PASS expert_replication")


def check_cp_decode_int8_cache():
    """CP decode with int8 quantized cache == single-device int8 decode."""
    mesh = make_test_mesh(2, 4)
    cfg = get_config("qwen3_0_6b").reduced().replace(kv_cache_dtype="int8")
    key = jax.random.PRNGKey(21)
    p = attention.attn_init(key, cfg, jnp.float32)
    b, clen = 4, 32
    c1 = attention.init_layer_cache(cfg, b, clen, jnp.float32)
    c2 = jax.tree.map(jnp.copy, c1)
    for t in range(6):
        x = jax.random.normal(jax.random.fold_in(key, 30 + t),
                              (b, 1, cfg.d_model))
        lengths = jnp.full((b,), t, jnp.int32)
        o1, c1 = attention.attn_decode_step(p, cfg, c1, x, lengths, None)
        o2, c2 = attention.attn_decode_step_cp(p, cfg, c2, x, lengths, None,
                                               mesh)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(c1["k"]), np.asarray(c2["k"]))
    print("PASS cp_decode_int8")


def check_serving_engine_on_mesh():
    """End-to-end distributed serving (the paper's system): the engine on a
    (2,4) mesh with expert-parallel MoE + sharded params generates the same
    tokens as the single-device engine."""
    from repro.serving.engine import EngineConfig, ServingEngine
    mesh = make_test_mesh(2, 4)
    base = get_config("qwen3_moe_30b_a3b").reduced().replace(
        capacity_factor=8.0, kv_cache_shard="none")
    ecfg = EngineConfig(max_batch=2, prefill_len=8, max_cache=24,
                        track_experts=False)
    prompts = [np.arange(5) % base.vocab_size,
               (np.arange(7) * 3) % base.vocab_size]

    # decentralized = the paper's design; a2a_pipelined = the overlapped
    # schedule end-to-end (prefill pipelines, decode falls back); both run
    # with donation + the gather decode fast path (engine defaults)
    for ep in ("decentralized", "a2a_pipelined"):
        cfg = base.replace(expert_parallel=ep, ep_microchunks=2)
        outs = {}
        for name, m in (("single", None), ("mesh", mesh)):
            eng = ServingEngine(cfg, ecfg, rng=jax.random.PRNGKey(5), mesh=m)
            for p_ in prompts:
                eng.submit(p_, max_new_tokens=4)
            done = sorted(eng.run_until_done(), key=lambda r: r.uid)
            outs[name] = [r.generated for r in done]
        assert outs["single"] == outs["mesh"], (ep, outs)
    print("PASS serving_engine_on_mesh")


def check_quantized_weights_on_mesh():
    """ISSUE 5: int8-quantized expert shards ride the expert-parallel
    schedules unchanged — QuantTensor payload+scale leaves shard over the
    expert axis through the same rank-3 in_specs, activations stay fp, and
    the mesh engine generates the same tokens as the single-device engine
    serving the same quantized store (which in turn is token-identical to
    the fake-quant fp reference, tests/test_quant.py)."""
    from repro.serving.engine import EngineConfig, ServingEngine
    mesh = make_test_mesh(2, 4)
    base = get_config("qwen3_moe_30b_a3b").reduced().replace(
        capacity_factor=8.0, kv_cache_shard="none", weight_quant="int8",
        weight_quant_block=64)
    ecfg = EngineConfig(max_batch=2, prefill_len=8, max_cache=24,
                        track_experts=False)
    prompts = [np.arange(5) % base.vocab_size,
               (np.arange(7) * 3) % base.vocab_size]
    for ep in ("decentralized", "a2a_pipelined"):
        cfg = base.replace(expert_parallel=ep, ep_microchunks=2)
        outs = {}
        for name, m in (("single", None), ("mesh", mesh)):
            eng = ServingEngine(cfg, ecfg, rng=jax.random.PRNGKey(5), mesh=m)
            from repro.core import quant as quant_lib
            assert any(isinstance(l, quant_lib.QuantTensor)
                       for l in jax.tree.leaves(
                           eng.params,
                           is_leaf=lambda x: isinstance(x, quant_lib.QuantTensor)))
            for p_ in prompts:
                eng.submit(p_, max_new_tokens=4)
            done = sorted(eng.run_until_done(), key=lambda r: r.uid)
            outs[name] = [r.generated for r in done]
        assert outs["single"] == outs["mesh"], (ep, outs)
    print("PASS quantized_weights_on_mesh")


def check_analysis_rules_on_mesh():
    """ISSUE 6: the static analyzer's mesh-aware rules hold on the real
    8-device serving programs — every donated cache leaf aliases (R1), the
    per-kind collective bytes match core/perf_model's schedule + serve-mode
    TP prediction within tolerance (R2), and no expert-weight slice is ever
    all-gathered (R6)."""
    from repro.analysis import programs as programs_lib
    from repro.analysis.collectives import CollectiveBudgetRule
    from repro.analysis.donation import DonationAliasRule
    from repro.analysis.framework import run_rules
    from repro.analysis.sharding_lint import ShardingLintRule
    from repro.core import perf_model
    from repro.launch import hlo as hlo_lib

    mesh = make_test_mesh(2, 4)
    cfg_kw = dict(capacity_factor=8.0, kv_cache_shard="none")
    progs = [programs_lib.trace_program(v, mesh=mesh, cfg_kw=cfg_kw)
             for v in ("unified", "decode")]
    rep = run_rules([DonationAliasRule(), CollectiveBudgetRule(),
                     ShardingLintRule()], progs)
    assert rep.ok, rep.summary()
    # non-vacuous: the programs really contain the predicted expert psum
    # traffic, and the prediction is nonzero on this mesh
    for prog in progs:
        assert hlo_lib.analyze(prog.hlo_text).coll["all-reduce"] > 0, prog.name
        pred = perf_model.predicted_collective_bytes(
            prog.cfg, batch=prog.batch, seq=prog.seq,
            n_exp_shards=prog.n_exp_shards,
            n_batch_shards=prog.n_batch_shards)
        assert pred.get("all-reduce", 0) > 0, prog.name
    print("PASS analysis_rules_on_mesh")


CHECKS = [
    check_expert_parallel_schedules,
    check_a2a_pipelined_token_exact,
    check_padded_experts_dead_on_mesh,
    check_expert_replication_overlap,
    check_serving_engine_on_mesh,
    check_quantized_weights_on_mesh,
    check_cp_decode_int8_cache,
    check_cp_decode_matches_single_device,
    check_cp_decode_ring_window,
    check_sharded_train_step_matches_single,
    check_params_pspec_structure,
    check_data_sharded_batch,
    check_analysis_rules_on_mesh,
]


def main():
    names = sys.argv[1:]
    for c in CHECKS:
        if names and c.__name__ not in names:
            continue
        c()
    print("ALL_OK")


if __name__ == "__main__":
    main()

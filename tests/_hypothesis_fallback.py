"""Minimal stand-in for the ``hypothesis`` API surface this suite uses.

``requirements-dev.txt`` installs the real library; where it is absent
(e.g. a hermetic container) the property tests degrade gracefully to a
fixed number of deterministic pseudo-random samples instead of erroring at
collection.  Only the strategies the suite actually uses are implemented:
``st.integers`` and ``st.sampled_from``.
"""
from __future__ import annotations


import numpy as np

FALLBACK_EXAMPLES = 5          # cap per test when running without hypothesis


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return lambda rng: int(rng.integers(min_value, max_value + 1))

    @staticmethod
    def sampled_from(elements):
        xs = list(elements)
        return lambda rng: xs[int(rng.integers(0, len(xs)))]


def given(**strats):
    def deco(test):
        # NB: no functools.wraps — pytest must see a zero-arg signature,
        # not the original one (it would treat drawn params as fixtures).
        def wrapper():
            n = min(getattr(wrapper, "_max_examples", FALLBACK_EXAMPLES),
                    FALLBACK_EXAMPLES)
            rng = np.random.default_rng(0)
            for _ in range(n):
                test(**{k: draw(rng) for k, draw in strats.items()})
        wrapper.__name__ = test.__name__
        wrapper.__doc__ = test.__doc__
        return wrapper
    return deco


def settings(max_examples: int = FALLBACK_EXAMPLES, **_ignored):
    def deco(test):
        test._max_examples = max_examples
        return test
    return deco

"""ISSUE 4 tentpole: paged KV cache, block-table attention, prefix reuse.

Three layers of guarantees (docs/DESIGN.md §7):

  * **model** — ``forward_routed`` over a page pool + block tables is
    token-for-token equal to the contiguous cache (ragged page sizes that
    divide neither the prompt nor the cache included), for fp32 and the
    int8-quantized cache;
  * **host allocator / prefix tree** — ``serving/paging.py`` invariants:
    alloc/free/fork/cow never double-free, refcounts return the pool to
    fully free after every owner releases, lookups cap at
    ``len(prompt) - 1`` shared tokens, eviction is LRU and respects
    in-flight references;
  * **engine** — paged unified mode generates the same tokens as the
    contiguous unified engine under non-binding capacity, requests
    sharing a system prompt skip the shared prefix's prefill (prefix-hit
    accounting), identical prompts share the partial tail page via
    copy-on-write, and admission is gated on free pages (with LRU
    prefix-cache eviction under pressure).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:  # requirements-dev.txt; degrade to fixed samples when absent
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.paging import PageAllocator, PrefixCache

MOE_ARCH = "qwen3_moe_30b_a3b"
DENSE_ARCH = "qwen3_0_6b"


def nocap(arch, **kw):
    return get_config(arch).reduced().replace(capacity_factor=8.0, **kw)


def generations(done):
    return {r.uid: list(r.generated) for r in done}


# ---------------------------------------------------------------------------
# model level: paged forward_routed == contiguous reference
# ---------------------------------------------------------------------------

def _run_paged_chunks(model, params, toks, page_size, max_cache, chunk):
    """Stream ``toks`` (B, S) through a paged pool in ``chunk``-token
    blocks; rows get disjoint page ranges.  Returns (logits, cache, bt)."""
    b, s = toks.shape
    nb = -(-max_cache // page_size)
    cache = model.init_paged_cache(b * nb, page_size)
    bt = jnp.asarray(np.arange(b * nb).reshape(b, nb), jnp.int32)
    logits = None
    for lo in range(0, s, chunk):
        hi = min(lo + chunk, s)
        logits, cache, _ = model.forward_routed(
            params, {"tokens": toks[:, lo:hi],
                     "lengths": jnp.full((b,), lo, jnp.int32),
                     "seg_lens": jnp.full((b,), hi - lo, jnp.int32),
                     "block_tables": bt}, cache)
    return logits, cache, bt


@pytest.mark.parametrize("arch", [MOE_ARCH, DENSE_ARCH])
@pytest.mark.parametrize("page_size", [5, 8])   # 5 divides neither 8 nor 32
def test_paged_forward_matches_contiguous(arch, page_size):
    cfg = nocap(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s, c = 2, 8, 32
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 100, (b, s)),
                       jnp.int32)
    logits_r, cache_r, _ = model.prefill_routed(
        params, {"tokens": toks}, model.init_cache(b, c))
    for chunk in (3, 8):
        logits_p, cache_p, bt = _run_paged_chunks(model, params, toks,
                                                  page_size, c, chunk)
        v = cfg.vocab_size
        np.testing.assert_array_equal(
            np.argmax(np.asarray(logits_r[:, -1, :v]), -1),
            np.argmax(np.asarray(logits_p[:, :v]), -1))
        # gathered pages hold the same K as the contiguous cache slots
        nb = bt.shape[1]
        kg = np.asarray(cache_p["k"])[:, np.asarray(bt).reshape(-1)].reshape(
            cfg.num_layers, b, nb * page_size,
            cfg.num_kv_heads, cfg.head_dim)
        np.testing.assert_allclose(np.asarray(cache_r["k"])[:, :, :s],
                                   kg[:, :, :s], atol=1e-5)


def test_paged_rows_share_prefix_pages_exactly():
    """Two rows whose block tables alias the same physical pages for their
    common prefix attend identical K/V — the mechanism behind prefix-cache
    reuse, checked at the model level: row 1 maps row 0's prefix pages and
    only computes its divergent tail, yet its logits equal a full
    recompute."""
    cfg = nocap(MOE_ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    ps, nb = 4, 4
    rng = np.random.default_rng(2)
    shared = rng.integers(0, 100, 8)            # 2 full pages
    tail_a, tail_b = rng.integers(0, 100, 3), rng.integers(0, 100, 3)
    pa = np.concatenate([shared, tail_a])
    pb = np.concatenate([shared, tail_b])

    # reference: each prompt alone through the contiguous cache
    refs = {}
    for key, p in (("a", pa), ("b", pb)):
        lg, _, _ = model.prefill_routed(
            params, {"tokens": jnp.asarray(p[None], jnp.int32)},
            model.init_cache(1, nb * ps))
        refs[key] = int(jnp.argmax(lg[0, -1, :cfg.vocab_size]))

    cache = model.init_paged_cache(8, ps)
    bt = jnp.asarray([[0, 1, 2, 3], [0, 1, 4, 5]], jnp.int32)  # shared 0,1
    # row 0 prefills the whole prompt a (writes pages 0,1,2)
    lg, cache, _ = model.forward_routed(
        params, {"tokens": jnp.asarray(pa[None], jnp.int32),
                 "lengths": jnp.zeros((1,), jnp.int32),
                 "seg_lens": jnp.full((1,), len(pa), jnp.int32),
                 "block_tables": bt[:1]}, cache)
    assert int(jnp.argmax(lg[0, :cfg.vocab_size])) == refs["a"]
    # row 1 maps pages 0,1 and computes ONLY its tail at offset 8
    blk = jnp.zeros((2, 3), jnp.int32).at[1].set(jnp.asarray(tail_b))
    lg, cache, _ = model.forward_routed(
        params, {"tokens": blk,
                 "lengths": jnp.asarray([0, len(shared)], jnp.int32),
                 "seg_lens": jnp.asarray([0, 3], jnp.int32),
                 "block_tables": bt}, cache)
    assert int(jnp.argmax(lg[1, :cfg.vocab_size])) == refs["b"]


def test_int8_unified_block_step_contiguous_and_paged():
    """Satellite: the int8 cache path under the unified BLOCK step
    (previously only the decode step was exercised).  Chunked prefill
    attends the *dequantized* cache while whole-prompt prefill attends
    full-precision K/V, so later chunks see quantization error the
    reference does not: the contract is argmax-equal logits plus
    dequantized caches agreeing within a few quantization quanta — and
    the paged int8 path must match the contiguous int8 path bit-exactly
    on the stored quantized values."""
    cfg = nocap(MOE_ARCH, kv_cache_dtype="int8")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s, c = 2, 8, 32
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 100, (b, s)),
                       jnp.int32)
    logits_r, cache_r, _ = model.prefill_routed(
        params, {"tokens": toks}, model.init_cache(b, c))
    # contiguous unified block path, ragged chunk
    cache_u = model.init_cache(b, c)
    for lo in range(0, s, 3):
        hi = min(lo + 3, s)
        logits_u, cache_u, _ = model.forward_routed(
            params, {"tokens": toks[:, lo:hi],
                     "lengths": jnp.full((b,), lo, jnp.int32),
                     "seg_lens": jnp.full((b,), hi - lo, jnp.int32)},
            cache_u)
    v = cfg.vocab_size
    np.testing.assert_array_equal(
        np.argmax(np.asarray(logits_r[:, -1, :v]), -1),
        np.argmax(np.asarray(logits_u[:, :v]), -1))
    dq = lambda cc, sl: (np.asarray(cc["k"])[:, :, :sl].astype(np.float32)
                         * np.asarray(cc["k_scale"])[:, :, :sl])
    scale = float(np.asarray(cache_r["k_scale"]).max())
    np.testing.assert_allclose(dq(cache_r, s), dq(cache_u, s),
                               atol=4 * scale)
    assert cache_u["k"].dtype == jnp.int8

    # paged int8 == contiguous int8, bit-exact on the quantized values
    ps_ = 5
    logits_p, cache_p, bt = _run_paged_chunks(model, params, toks, ps_, c, 3)
    np.testing.assert_array_equal(
        np.argmax(np.asarray(logits_u[:, :v]), -1),
        np.argmax(np.asarray(logits_p[:, :v]), -1))
    nb = bt.shape[1]
    for leaf in ("k", "k_scale", "v", "v_scale"):
        gathered = np.asarray(cache_p[leaf])[:, np.asarray(bt).reshape(-1)]
        gathered = gathered.reshape((cfg.num_layers, b, nb * ps_)
                                    + gathered.shape[3:])
        np.testing.assert_array_equal(np.asarray(cache_u[leaf])[:, :, :s],
                                      gathered[:, :, :s])


# ---------------------------------------------------------------------------
# host side: allocator + prefix tree
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_allocator_invariants_under_random_ops(seed):
    """Property: any interleaving of alloc/free/fork/cow keeps refcounts
    exactly equal to the number of outstanding owner references, never
    double-frees, and returns the pool to fully free once every owner
    releases."""
    rng = np.random.default_rng(seed)
    n = 16
    a = PageAllocator(n)
    owners: list[list[int]] = []     # each inner list holds one ref/page
    for _ in range(60):
        op = int(rng.integers(0, 4))
        if op == 0:
            want = int(rng.integers(0, 5))
            got = a.alloc(want)
            if got is None:
                assert a.free_pages < want
            else:
                assert len(set(got)) == want
                owners.append(list(got))
        elif op == 1 and owners:
            a.free(owners.pop(int(rng.integers(0, len(owners)))))
        elif op == 2 and owners:
            pages = owners[int(rng.integers(0, len(owners)))]
            a.fork(pages)
            owners.append(list(pages))
        elif op == 3 and owners:
            oi = int(rng.integers(0, len(owners)))
            if owners[oi]:
                pi = int(rng.integers(0, len(owners[oi])))
                page = owners[oi][pi]
                if a.refcount(page) == 1 or a.free_pages >= 1:
                    new_page, copied = a.writable(page)
                    assert copied == (new_page != page)
                    owners[oi][pi] = new_page
        # refcount == outstanding owner references, every step
        for p in range(n):
            assert a.refcount(p) == sum(o.count(p) for o in owners)
        assert a.pages_in_use == len({p for o in owners for p in o})
    for o in owners:
        a.free(o)
    assert a.free_pages == n and a.pages_in_use == 0


def test_allocator_rejects_double_free_and_bad_fork():
    a = PageAllocator(4)
    pages = a.alloc(2)
    a.free(pages)
    with pytest.raises(ValueError, match="double free"):
        a.free([pages[0]])
    with pytest.raises(ValueError, match="unreferenced"):
        a.fork([pages[0]])
    assert a.alloc(5) is None and a.free_pages == 4


def test_prefix_cache_lookup_caps_at_prompt_minus_one():
    """A fully cached prompt still recomputes >= 1 token (the request
    needs a logit to sample its first generated token from)."""
    a = PageAllocator(8)
    pc = PrefixCache(4, a)
    prompt = np.arange(8, dtype=np.int32)        # exactly 2 pages
    pages = a.alloc(2)
    pc.insert(prompt, pages)
    hit = pc.lookup(prompt)                      # same prompt again
    assert hit.tokens == 4 and len(hit.pages) == 1   # NOT both pages
    a.free(hit.pages)
    # a longer prompt with the same leading pages shares both
    hit2 = pc.lookup(np.arange(12, dtype=np.int32))
    assert hit2.tokens == 8 and len(hit2.pages) == 2
    a.free(hit2.pages)


def test_prefix_cache_tail_record_and_first_writer_wins():
    a = PageAllocator(8)
    pc = PrefixCache(4, a)
    prompt = np.arange(6, dtype=np.int32)        # 1 full page + 2-token tail
    pages = a.alloc(2)
    pc.insert(prompt, pages[:1], tail_page=pages[1], tail_len=2)
    assert pc.cached_pages == 2
    # identical prompt: 4 full-page tokens + 1 usable tail token (cap 5)
    hit = pc.lookup(prompt)
    assert hit.tokens == 5 and hit.tail_len == 1 and hit.tail_page == pages[1]
    a.free(hit.pages)
    a.free([hit.tail_page])
    # a second insert of the same content must not replace pages
    other = a.alloc(2)
    added = pc.insert(prompt, other[:1], tail_page=other[1], tail_len=2)
    assert added == 0
    a.free(other)
    pc.clear()
    a.free(pages)
    assert a.free_pages == 8


def test_prefix_cache_clear_does_not_count_as_eviction():
    """clear() is shutdown / benchmark-warmup housekeeping: reported
    eviction counts must only ever reflect admission pressure."""
    a = PageAllocator(4)
    pc = PrefixCache(2, a)
    pages = a.alloc(2)
    pc.insert(np.arange(4, dtype=np.int32), pages)
    a.free(pages)
    assert pc.clear() == 2 and pc.evictions == 0 and a.free_pages == 4


def test_prefix_cache_reclaimable_counts_only_unpinned_pages():
    a = PageAllocator(4)
    pc = PrefixCache(2, a)
    p1, p2 = a.alloc(1), a.alloc(1)
    pc.insert(np.array([1, 2], np.int32), p1)
    pc.insert(np.array([3, 4], np.int32), p2)
    a.free(p2)                     # p2: tree-only; p1: tree + our ref
    assert pc.reclaimable_pages() == 1
    a.free(p1)
    assert pc.reclaimable_pages() == 2


def test_prefix_cache_evicts_lru_and_respects_inflight_refs():
    a = PageAllocator(4)
    pc = PrefixCache(2, a)
    p1, p2 = a.alloc(1), a.alloc(1)
    pc.insert(np.array([1, 2], np.int32), p1)
    pc.insert(np.array([3, 4], np.int32), p2)
    a.free(p1), a.free(p2)                       # only the tree holds them
    pc.lookup(np.array([1, 2, 9], np.int32))     # touches p1 (newer)
    a.free(p1)                                   # give lookup ref back
    pc.evict(3)                                  # need 3 free -> drop LRU p2
    assert a.free_pages == 3 and pc.evictions == 1
    assert a.refcount(p2[0]) == 0 and a.refcount(p1[0]) == 1
    # an in-flight reference keeps an evicted page off the free list
    hold = pc.lookup(np.array([1, 2, 9], np.int32))
    assert hold.pages == (p1[0],)
    pc.evict(4)                                  # tree ref dropped...
    assert a.free_pages == 3                     # ...but page still held
    a.free(hold.pages)
    assert a.free_pages == 4                     # returns at release


# ---------------------------------------------------------------------------
# engine level
# ---------------------------------------------------------------------------

def _engine(cfg, **kw):
    eng_kw = dict(max_batch=2, prefill_len=8, max_cache=32,
                  async_steps=False, chunk_len=3)
    eng_kw.update(kw)
    return ServingEngine(cfg, EngineConfig(**eng_kw),
                         rng=jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", [MOE_ARCH, DENSE_ARCH])
def test_paged_engine_matches_contiguous_unified(arch):
    """Paged == contiguous token equality through the full engine, with a
    page size dividing neither prompts nor max_cache, mixed-length
    prompts, and a mid-flight arrival (mixed prefill/decode batches)."""
    cfg = nocap(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 100, n) for n in (8, 5, 8, 7)]
    outs = {}
    for name, kw in (("contig", {}), ("paged", dict(paged=True,
                                                    page_size=5))):
        eng = _engine(cfg, **kw)
        eng.submit(prompts[0], max_new_tokens=6)
        eng.step()
        eng.step()
        for p in prompts[1:]:
            eng.submit(p, max_new_tokens=4)
        outs[name] = generations(eng.run_until_done())
    assert outs["paged"] == outs["contig"]


def test_paged_engine_async_and_donation_off_are_token_neutral():
    cfg = nocap(MOE_ARCH)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, 100, 7) for _ in range(3)]
    outs = []
    for kw in (dict(), dict(async_steps=True), dict(donate_buffers=False)):
        eng = _engine(cfg, paged=True, page_size=4, **kw)
        for p in prompts:
            eng.submit(p, max_new_tokens=5)
        outs.append(generations(eng.run_until_done()))
    assert outs[0] == outs[1] == outs[2]


def test_shared_system_prompt_skips_prefill_via_prefix_hits():
    """The serving shape the prefix cache exists for: requests sharing a
    system prompt map their leading blocks to the same pages and skip the
    shared prefill.  Tokens must equal the contiguous engine (which
    recomputes everything); skipped work is recorded in
    ``prefix_hit_tokens`` and the ``prefill_tokens`` gap."""
    cfg = nocap(MOE_ARCH)
    rng = np.random.default_rng(7)
    sysp = rng.integers(0, 100, 13)              # 3 full pages at ps=4
    prompts = [np.concatenate([sysp, rng.integers(0, 100, 6)])
               for _ in range(3)]

    def run(**kw):
        eng = _engine(cfg, prefill_len=32, chunk_len=4, **kw)
        for p in prompts:                        # sequential completions
            eng.submit(p, max_new_tokens=4)
            eng.run_until_done()
        return generations(eng._all.values()), eng

    ref, eng_c = run()
    pag, eng_p = run(paged=True, page_size=4)
    assert pag == ref
    ps = eng_p.paged_stats()
    aligned = (len(sysp) // 4) * 4               # 12 page-aligned tokens
    assert ps["prefix_hits"] == 2                # both followers hit
    assert ps["prefix_hit_tokens"] >= 2 * aligned
    assert ps["prefix_hit_tokens"] >= len(sysp)  # acceptance criterion
    assert (eng_c.stats["prefill_tokens"] - eng_p.stats["prefill_tokens"]
            == ps["prefix_hit_tokens"])


def test_identical_prompts_share_partial_tail_page_via_cow():
    """A repeat of an exact prompt shares its partial tail page too: the
    sharer copies the page (copy-on-write — the owner may still be
    appending decode tokens to the original) and recomputes only the final
    prompt token."""
    cfg = nocap(MOE_ARCH)
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, 100, 10)            # ps=4: 2 pages + 2 tail

    def run(**kw):
        eng = _engine(cfg, prefill_len=32, chunk_len=4, **kw)
        for _ in range(2):
            eng.submit(prompt, max_new_tokens=4)
            eng.run_until_done()
        return generations(eng._all.values()), eng

    ref, _ = run()
    pag, eng = run(paged=True, page_size=4)
    assert pag == ref
    s = eng.paged_stats()
    assert s["cow_copies"] == 1
    # 8 full-page tokens + 1 tail token (cap at len-1 = 9)
    assert s["prefix_hit_tokens"] == 9


def test_admission_gated_on_free_pages_with_eviction():
    """A pool too small for two concurrent requests admits them one at a
    time (FIFO, no deadlock), evicting LRU prefix-cache pages under
    pressure — and still completes everything with the right tokens."""
    cfg = nocap(MOE_ARCH)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, 100, 8) for _ in range(3)]
    ref_eng = _engine(cfg)
    for p in prompts:
        ref_eng.submit(p, max_new_tokens=5)
    ref = generations(ref_eng.run_until_done())

    # 4 pages of 4 tokens: one request needs ceil((8+5-1)/4) = 3 pages,
    # so only one fits at a time and every completion's cached pages must
    # be evicted to admit the next
    eng = _engine(cfg, paged=True, page_size=4, num_pages=4)
    for p in prompts:
        eng.submit(p, max_new_tokens=5)
    got = generations(eng.run_until_done())
    assert got == ref
    s = eng.paged_stats()
    assert s["prefix_evictions"] > 0
    assert s["pages_hwm"] <= 4


def test_waiting_request_neither_drains_tree_nor_inflates_lookups():
    """A queued request that merely has to wait for in-flight pages must
    NOT evict the prefix cache on every retry (eviction cannot free
    pinned pages) and must count as ONE prefix lookup when admitted, not
    one per scheduler iteration — hit-rate stats count requests."""
    cfg = nocap(MOE_ARCH)
    rng = np.random.default_rng(21)
    eng = _engine(cfg, paged=True, page_size=4, num_pages=6, chunk_len=8)
    eng.submit(rng.integers(0, 100, 8), max_new_tokens=5)   # 3 pages
    eng.run_until_done()
    assert eng.prefix.cached_pages == 2                     # R1 cached
    eng.submit(rng.integers(0, 100, 8), max_new_tokens=9)   # 4 pages
    eng.step()                                              # R2 admitted
    eng.submit(rng.integers(0, 100, 8), max_new_tokens=5)   # needs 3
    for _ in range(3):                                      # R3 must wait:
        eng.step()                  # free 0 + reclaimable 2 < need 3
    assert eng.slots.count(None) == 1 and len(eng.queue) == 1
    # tree intact: R1's 2 cached pages survive, plus R2's own prefill
    # insert (its pages are pinned, so reclaimable stays 2 < need 3)
    assert eng.prefix.cached_pages == 4
    assert eng.prefix.reclaimable_pages() == 2
    assert eng.prefix.evictions == 0
    assert eng.stats["prefix_lookups"] == 2                 # R1, R2 only
    done = eng.run_until_done()
    assert len(done) == 3                                   # R3 admitted
    assert eng.stats["prefix_lookups"] == 3


def test_equal_pool_bytes_admit_more_concurrent_requests():
    """The capacity story: at the contiguous layout's pool bytes
    (max_batch * max_cache tokens), short requests leave most of a
    contiguous row's reservation unused — the paged engine admits more
    rows concurrently from the same bytes."""
    cfg = nocap(MOE_ARCH)
    rng = np.random.default_rng(13)
    # contiguous baseline: 2 rows x 32 slots = 64 token slots
    # paged at the same bytes: 16 pages x 4 tokens; a (5 prompt + 3 new)
    # request needs ceil(7/4) = 2 pages -> 4 concurrent rows fit twice over
    eng = ServingEngine(cfg, EngineConfig(
        max_batch=4, prefill_len=8, max_cache=32, async_steps=False,
        chunk_len=4, paged=True, page_size=4, num_pages=16),
        rng=jax.random.PRNGKey(0))
    for _ in range(4):
        eng.submit(rng.integers(0, 100, 5), max_new_tokens=3)
    eng.step()
    assert sum(r is not None for r in eng.slots) == 4   # all concurrent
    assert eng.allocator.pages_in_use == 8              # half the pool
    done = eng.run_until_done()
    assert len(done) == 4


def test_paged_requires_unified_and_validates_pool():
    cfg = nocap(MOE_ARCH)
    with pytest.raises(ValueError, match="unified"):
        ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                        max_cache=32, unified_step=False,
                                        paged=True))
    with pytest.raises(ValueError, match="page_size"):
        ServingEngine(cfg, EngineConfig(max_batch=2, prefill_len=8,
                                        max_cache=32, paged=True,
                                        page_size=0))
    eng = _engine(cfg, paged=True, page_size=4, num_pages=2)
    with pytest.raises(ValueError, match="pages"):
        eng.submit(np.arange(8), max_new_tokens=5)      # needs 3 > 2 pages


def test_throughput_apportions_mixed_time_by_token_share():
    """Satellite fix: per-phase times must PARTITION the measured work
    time — reciprocals of the two rates weighted by token counts sum to
    prefill_s + decode_s + mixed_s, instead of double-charging mixed_s to
    both phases."""
    cfg = nocap(MOE_ARCH)
    eng = _engine(cfg, chunk_len=4)
    rng = np.random.default_rng(15)
    eng.submit(rng.integers(0, 100, 8), max_new_tokens=8)
    eng.step()
    eng.step()
    eng.submit(rng.integers(0, 100, 8), max_new_tokens=4)  # mixed iters
    eng.run_until_done()
    s = eng.stats
    assert s["mixed_s"] > 0.0 and s["mixed_prefill_tokens"] > 0
    assert s["mixed_decode_tokens"] > 0
    tp = eng.throughput()
    work = s["prefill_s"] + s["decode_s"] + s["mixed_s"]
    recon = (s["prefill_tokens"] / tp["prefill_tok_per_s"]
             + s["decode_tokens"] / tp["decode_tok_per_s"])
    assert recon == pytest.approx(work, rel=1e-6)

"""ISSUE 7 tentpole: resilient serving — priority preemption with
prefix-cache restore, deadlines/cancellation, and deterministic fault
injection (docs/DESIGN.md §10).

Three layers of guarantees:

  * **scheduler** (``serving/scheduler.py``) — victim selection is total
    and fair (priority asc, preempt-epoch asc, newest-first within a
    class); the admission queue orders by (priority desc, seq asc) so a
    preempted request re-enters ahead of later same-priority arrivals;
  * **engine** — a preempted-and-restored greedy request emits the EXACT
    token stream of an uncontended run (restore = block-table remap +
    at most one tail re-prefill chunk); cancel and deadline expiry
    release pages exactly once and leave prefix-tree pages alive;
    overcommit admission completes every request under pool pressure;
  * **faults** (``serving/faults.py``) — allocator exhaustion, failed
    dispatch, and NaN/Inf logits are absorbed by engine guards with
    token-identical recovery, and every failure path returns the page
    pool to fully free.
"""
import numpy as np
import pytest
try:  # requirements-dev.txt; degrade to fixed samples when absent
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

import jax

from repro.configs.base import get_config
from repro.serving import scheduler as sched
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.faults import Fault, FaultPlan, InjectedFault

ARCH = "qwen3_moe_30b_a3b"


def nocap(arch=ARCH, **kw):
    return get_config(arch).reduced().replace(capacity_factor=8.0, **kw)


def _engine(cfg, *, fault_plan=None, **kw):
    eng_kw = dict(max_batch=2, prefill_len=8, max_cache=32, async_steps=False,
                  unified_step=True, chunk_len=3, page_size=4)
    eng_kw.update(kw)
    return ServingEngine(cfg, EngineConfig(**eng_kw),
                         rng=jax.random.PRNGKey(0), fault_plan=fault_plan)


def _prompts(seed=0, lens=(7, 5)):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 50, n) for n in lens]


def _drain_clean(eng):
    """Post-drain hygiene: clear the prefix tree, then the pool must be
    fully free with consistent refcounts."""
    eng.prefix.clear()
    assert eng.allocator.fully_free, \
        f"{eng.allocator.num_pages - eng.allocator.free_pages} pages leaked"
    eng.allocator.check_consistent()


def _step_until_decoding(eng, req, max_steps=64):
    """Step until ``req`` occupies a slot with its prefill complete."""
    for _ in range(max_steps):
        eng.step()
        slot = next((i for i, r in enumerate(eng.slots) if r is req), None)
        if (slot is not None
                and eng.prefill_pos[slot] >= len(eng.slot_ctx[slot])):
            return slot
    raise AssertionError("request never reached decode")


# ---------------------------------------------------------------------------
# host side: fault plans and the scheduler
# ---------------------------------------------------------------------------

def test_fault_plan_validation():
    with pytest.raises(ValueError):
        Fault(1, "bogus-site")
    with pytest.raises(ValueError):
        Fault(0, "nan")                       # steps are 1-based
    with pytest.raises(ValueError):
        Fault(1, "nan", kind="minus-zero")
    with pytest.raises(ValueError):           # one fault per (step, site)
        FaultPlan([Fault(3, "nan"), Fault(3, "nan", rows=(1,))])
    assert np.isnan(Fault(1, "nan", kind="nan").value)
    assert np.isinf(Fault(1, "nan", kind="inf").value)


def test_fault_plan_poll_fires_once():
    plan = FaultPlan([Fault(2, "alloc"), Fault(4, "nan", rows=(0,))])
    assert plan.poll(1, "alloc") is None
    assert plan.poll(2, "alloc") is not None
    assert plan.poll(2, "alloc") is None      # fired exactly once
    assert not plan.all_fired()
    assert [f.step for f in plan.unfired()] == [4]
    with pytest.raises(InjectedFault):
        plan.maybe_raise(4, "nan")
    assert plan.all_fired()


def test_fault_plan_random_is_deterministic():
    a = FaultPlan.random(7, n_faults=5, max_step=20, max_batch=4)
    b = FaultPlan.random(7, n_faults=5, max_step=20, max_batch=4)
    assert [(f.step, f.site, f.rows, f.kind) for f in a] \
        == [(f.step, f.site, f.rows, f.kind) for f in b]
    assert len(a) == 5
    assert len({(f.step, f.site) for f in a}) == 5    # distinct keys


def test_pages_arithmetic():
    assert sched.pages_for(0, 4) == 0
    assert sched.pages_for(1, 4) == 1
    assert sched.pages_for(8, 4) == 2
    assert sched.pages_for(9, 4) == 3
    # lifetime covers context + all new tokens minus the unsampled last
    assert sched.lifetime_pages(7, 8, 4) == sched.pages_for(14, 4)
    # a finished request (nothing left to emit) still holds its context
    assert sched.lifetime_pages(7, 0, 4) == sched.pages_for(7, 4)


def test_select_victim_ordering():
    rows = [sched.RunningRow(0, priority=1, epoch=0, seq=1),
            sched.RunningRow(1, priority=0, epoch=0, seq=2),
            sched.RunningRow(2, priority=0, epoch=0, seq=3)]
    # lowest priority first; within it, the NEWEST request yields
    assert sched.select_victim(rows) == 2
    # a row already preempted this epoch is spared over same-class peers
    rows[2] = sched.RunningRow(2, priority=0, epoch=5, seq=3)
    assert sched.select_victim(rows) == 1
    # `below` restricts to strictly lower priority than the claimant
    assert sched.select_victim(rows, below=1) in (1, 2)
    assert sched.select_victim(rows, below=0) is None
    assert sched.select_victim(rows, below=2, exclude=(1, 2)) == 0
    assert sched.select_victim([]) is None


def test_admission_queue_orders_priority_then_seq():
    import types
    q = sched.AdmissionQueue()
    mk = lambda uid, pr, seq: types.SimpleNamespace(uid=uid, priority=pr,
                                                    seq=seq)
    q.append(mk(1, 0, 1))
    q.append(mk(2, 5, 2))
    q.append(mk(3, 0, 3))
    q.append(mk(4, 5, 4))
    assert [r.uid for r in q] == [2, 4, 1, 3]  # priority desc, FIFO within
    # a preempted request keeps its ORIGINAL seq: re-enters ahead of
    # later same-priority arrivals (uid 3), behind earlier ones (uid 1)
    q.append(mk(9, 0, 2))
    assert [r.uid for r in q] == [2, 4, 1, 9, 3]
    assert q.remove(4).uid == 4
    assert q.remove(4) is None
    assert q.popleft().uid == 2
    assert len(q) == 3 and bool(q)


# ---------------------------------------------------------------------------
# engine: preempt/restore, cancel, deadlines
# ---------------------------------------------------------------------------

def test_preempt_restore_token_identity():
    """The tentpole gate: preempt a decoding request, restore it through
    the prefix cache, and the greedy token stream is IDENTICAL to an
    uncontended run (restore = block-table remap + one tail re-prefill)."""
    cfg = nocap()
    p = _prompts()[0]
    base = _engine(cfg, paged=True)
    uid = base.submit(p, max_new_tokens=6)
    base.run_until_done()
    want = list(base._all[uid].generated)

    eng = _engine(cfg, paged=True)
    uid = eng.submit(p, max_new_tokens=6)
    req = eng._all[uid]
    _step_until_decoding(eng, req)
    assert eng.preempt(uid)
    assert req.status == "preempted" and req.preemptions == 1
    eng.run_until_done()
    assert req.status == "done"
    assert list(req.generated) == want
    st = eng.resilience_stats()
    assert st["preemptions"] == 1 and st["restores"] == 1
    # the restore actually reused cached pages (no full re-prefill)
    assert st["restore_hit_tokens"] > 0
    _drain_clean(eng)


def test_overcommit_pressure_completes_and_matches():
    """A pool too small for both lifetimes forces the scheduler to
    preempt under growth pressure; both requests still complete with the
    tokens of an uncontended run."""
    cfg = nocap()
    p1, p2 = _prompts()
    big = _engine(cfg, paged=True)
    a = big.submit(p1, max_new_tokens=8)
    b = big.submit(p2, max_new_tokens=8)
    big.run_until_done()
    want = [list(big._all[a].generated), list(big._all[b].generated)]

    eng = _engine(cfg, paged=True, num_pages=4, overcommit=True)
    a = eng.submit(p1, max_new_tokens=8)
    b = eng.submit(p2, max_new_tokens=8)
    eng.run_until_done()
    assert eng._all[a].status == eng._all[b].status == "done"
    assert [list(eng._all[a].generated), list(eng._all[b].generated)] == want
    assert eng.resilience_stats()["preemptions"] >= 1
    _drain_clean(eng)


def test_overcommit_admits_beyond_conservative_capacity():
    """The point of overcommit: lazy allocation admits concurrency the
    conservative lifetime reservation refuses.  Equal pool bytes, equal
    workload — only the admission policy differs."""
    cfg = nocap()
    p1, p2 = _prompts()
    kw = dict(paged=True, num_pages=4)
    eager = _engine(cfg, **kw)
    eager.submit(p1, max_new_tokens=8)
    eager.submit(p2, max_new_tokens=8)
    eager.run_until_done()
    lazy = _engine(cfg, overcommit=True, **kw)
    lazy.submit(p1, max_new_tokens=8)
    lazy.submit(p2, max_new_tokens=8)
    lazy.run_until_done()
    assert (lazy.resilience_stats()["active_hwm"]
            > eager.resilience_stats()["active_hwm"])


def test_cancel_queued_and_inflight_exactly_once():
    cfg = nocap()
    p1, p2 = _prompts()
    eng = _engine(cfg, paged=True)
    a = eng.submit(p1, max_new_tokens=6)
    b = eng.submit(p2, max_new_tokens=6)
    c = eng.submit(p1[:4], max_new_tokens=6)       # queued (max_batch=2)
    assert eng.cancel(c) and eng._all[c].status == "cancelled"
    assert not eng.cancel(c)                       # exactly once
    eng.step(); eng.step()
    assert eng.cancel(a) and eng._all[a].status == "cancelled"
    assert not eng.cancel(a)
    assert not eng.cancel(999_999)                 # unknown uid
    eng.run_until_done()
    assert eng._all[b].status == "done"
    assert eng._all[a].generated == [] or eng._all[a].status == "cancelled"
    _drain_clean(eng)


def test_cancel_keeps_prefix_tree_pages_alive():
    """Cancelling an in-flight request must not rip shared pages out of
    the prefix tree: a follower over the same prompt still hits."""
    cfg = nocap()
    p = _prompts()[0]
    eng = _engine(cfg, paged=True)
    uid = eng.submit(p, max_new_tokens=6)
    eng.run_until_done()                            # seeds the prefix tree
    want = list(eng._all[uid].generated)
    hits0 = eng.stats["prefix_hit_tokens"]

    mid = eng.submit(p, max_new_tokens=6)           # prefix hit on admit
    req = eng._all[mid]
    _step_until_decoding(eng, req)
    assert eng.cancel(mid)
    assert eng.stats["prefix_hit_tokens"] > hits0
    again = eng.submit(p, max_new_tokens=6)         # tree must still serve
    eng.run_until_done()
    assert eng.stats["prefix_hit_tokens"] > hits0
    assert list(eng._all[again].generated) == want
    _drain_clean(eng)


def test_deadline_expiry_queued_and_inflight():
    cfg = nocap()
    p1, p2 = _prompts()
    eng = _engine(cfg, paged=True)
    # already-elapsed deadline: expired on the first sweep, never admitted
    dead = eng.submit(p1, max_new_tokens=6, deadline_ms=0.0)
    live = eng.submit(p2, max_new_tokens=6)
    eng.step()
    assert eng._all[dead].status == "expired"
    assert eng._all[dead].first_token_s is None
    # in-flight expiry: generous deadline, then jump the engine clock
    slow = eng.submit(p1, max_new_tokens=20, deadline_ms=60_000.0)
    req = eng._all[slow]
    _step_until_decoding(eng, req)
    eng._now = lambda: req.deadline_s + 1.0
    eng.step()
    assert req.status == "expired"
    eng.run_until_done()
    assert eng._all[live].status == "done"
    st = eng.resilience_stats()
    assert st["expired"] == 2
    _drain_clean(eng)


# ---------------------------------------------------------------------------
# engine: fault guards
# ---------------------------------------------------------------------------

def test_fault_matrix_token_identical_recovery():
    """One of each site in a single run: the alloc stall delays
    admission, the failed dispatch re-runs the identical iteration, the
    poisoned row quarantines and retries — and the final tokens equal
    the fault-free run's exactly."""
    cfg = nocap()
    p = _prompts()[0]
    base = _engine(cfg, paged=True)
    uid = base.submit(p, max_new_tokens=6)
    base.run_until_done()
    want = list(base._all[uid].generated)

    plan = FaultPlan([Fault(1, "alloc"), Fault(3, "dispatch"),
                      Fault(5, "nan", rows=(0,))])
    eng = _engine(cfg, paged=True, fault_plan=plan)
    uid = eng.submit(p, max_new_tokens=6)
    eng.run_until_done()
    assert plan.all_fired(), plan.unfired()
    assert eng._all[uid].status == "done"
    assert list(eng._all[uid].generated) == want
    st = eng.resilience_stats()
    assert st["alloc_stalls"] == 1
    assert st["dispatch_failures"] == 1
    assert st["nan_quarantines"] >= 1
    _drain_clean(eng)


def test_nan_retry_limit_fails_request():
    """Persistent poison exhausts the retry budget: the row is failed,
    its pages are released, and the engine drains clean."""
    cfg = nocap()
    p = _prompts()[0]
    plan = FaultPlan([Fault(s, "nan") for s in range(1, 12)])
    eng = _engine(cfg, paged=True, fault_plan=plan, nan_retry_limit=2)
    uid = eng.submit(p, max_new_tokens=4)
    eng.run_until_done()
    assert eng._all[uid].status == "failed"
    assert eng.resilience_stats()["failed"] == 1
    _drain_clean(eng)


def test_fault_plan_requires_unified_engine():
    with pytest.raises(ValueError):
        _engine(nocap(), unified_step=False,
                fault_plan=FaultPlan([Fault(1, "nan")]))


def test_chaos_matrix_clean():
    """The CI chaos-smoke gate, as a tier-1 test: every scenario absorbs
    its faults with token-identical recovery and a fully-free pool."""
    from repro.serving.chaos import run_matrix
    assert run_matrix(ARCH, verbose=False) == []


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_property_preemption_fairness(seed):
    """Property: the scheduler never preempts a request while a
    strictly-lower-priority peer keeps running, and never the same
    request twice in a row while any peer shares its class (the
    preempt-epoch tiebreak) — audited from the engine's preempt log."""
    rng = np.random.default_rng(seed)
    cfg = nocap()
    eng = _engine(cfg, paged=True, num_pages=5, overcommit=True)
    uids = [eng.submit(rng.integers(0, 50, int(rng.integers(4, 8))),
                       max_new_tokens=8, priority=int(rng.integers(0, 3)))
            for _ in range(4)]
    eng.run_until_done()
    assert all(eng._all[u].status == "done" for u in uids)
    prev_uid = None
    for _step, uid, peers in eng.preempt_log:
        vp = eng._all[uid].priority
        assert all(p >= vp for _u, p in peers), \
            (uid, vp, peers, "victim outlived a lower-priority peer")
        if uid == prev_uid:
            # re-preempting the same request back-to-back is only fair
            # when it is strictly the lowest class left running
            assert all(p > vp for _u, p in peers), (uid, peers)
        prev_uid = uid
    _drain_clean(eng)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_property_continuous_load_completes(seed):
    """Property: under continuous arrivals into an overcommitted pool,
    every admitted request eventually completes (no starvation, no
    preempt/restore livelock)."""
    rng = np.random.default_rng(seed)
    cfg = nocap()
    eng = _engine(cfg, paged=True, num_pages=5, overcommit=True)
    pending = [(rng.integers(0, 50, int(rng.integers(3, 8))),
                int(rng.integers(0, 3))) for _ in range(6)]
    uids = []
    steps = 0
    while pending or eng.queue or any(s is not None for s in eng.slots):
        eng.step()
        steps += 1
        if pending and steps % int(rng.integers(2, 5)) == 0:
            p, pr = pending.pop(0)
            uids.append(eng.submit(p, max_new_tokens=6, priority=pr))
        assert steps < 2_000, "livelock: load never drained"
    eng.flush()
    assert all(eng._all[u].status == "done" for u in uids)
    _drain_clean(eng)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_property_pool_free_after_chaos(seed):
    """Property: after any randomized schedule of preempts, cancels, and
    injected faults, every page returns to the free list and refcounts
    stay consistent — no failure path leaks or double-frees."""
    rng = np.random.default_rng(seed)
    cfg = nocap()
    plan = FaultPlan.random(seed, n_faults=4, max_step=24, max_batch=2)
    eng = _engine(cfg, paged=True, num_pages=6, overcommit=True,
                  fault_plan=plan)
    uids = [eng.submit(rng.integers(0, 50, int(rng.integers(3, 8))),
                       max_new_tokens=6, priority=int(rng.integers(0, 3)))
            for _ in range(4)]
    for _ in range(30):
        eng.step()
        op = rng.random()
        victim = int(rng.choice(uids))
        if op < 0.15:
            eng.cancel(victim)
        elif op < 0.3:
            try:
                eng.preempt(victim)
            except ValueError:
                pass
        eng.allocator.check_consistent()       # invariant holds mid-flight
    eng.run_until_done()
    # every request reached a terminal state (done, cancelled, or failed
    # by the injected NaNs — all legal; leaking is not)
    from repro.serving.engine import TERMINAL_STATES
    assert all(eng._all[u].status in TERMINAL_STATES for u in uids)
    _drain_clean(eng)

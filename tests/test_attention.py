"""Attention: chunked (flash-style) vs dense oracle, ring-buffer decode,
GQA, RoPE/M-RoPE properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:  # requirements-dev.txt; degrade to fixed samples when absent
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models import attention, layers


def dense_oracle(q, k, v, q_pos, k_pos, window, scale):
    qp = q_pos[:, None, :, None]
    kp = k_pos[:, None, None, :]
    mask = kp <= qp
    if window is not None:
        mask = mask & (kp > qp - window)
    return attention.attend(q, k, v, mask, scale)


@pytest.mark.parametrize("s,window", [(64, None), (100, None), (64, 16),
                                      (256, 64), (130, 33)])
def test_chunked_attention_matches_dense(s, window):
    key = jax.random.PRNGKey(0)
    b, h, hd = 2, 4, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out_c = attention.attend_chunked(q, k, v, pos, pos, window, hd ** -0.5,
                                     q_chunk=32, k_chunk=48)
    out_d = dense_oracle(q, k, v, pos, pos, window, hd ** -0.5)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_d),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       s=st.integers(3, 80),
       qc=st.sampled_from([8, 17, 64]),
       kc=st.sampled_from([8, 31, 64]))
def test_chunked_attention_property(seed, s, qc, kc):
    key = jax.random.PRNGKey(seed)
    b, h, hd = 1, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out_c = attention.attend_chunked(q, k, v, pos, pos, None, hd ** -0.5,
                                     q_chunk=qc, k_chunk=kc)
    out_d = dense_oracle(q, k, v, pos, pos, None, hd ** -0.5)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_d),
                               rtol=5e-5, atol=5e-5)


def test_gqa_repeat():
    k = jnp.arange(2 * 3 * 2 * 4).reshape(2, 3, 2, 4)
    r = attention.gqa_repeat(k, 6)
    assert r.shape == (2, 3, 6, 4)
    np.testing.assert_array_equal(np.asarray(r[:, :, 0]), np.asarray(r[:, :, 2]))
    np.testing.assert_array_equal(np.asarray(r[:, :, 3]), np.asarray(r[:, :, 5]))


def test_ring_buffer_decode_matches_full_cache():
    """Sliding-window decode with a ring cache == full cache + window mask."""
    cfg = get_config("qwen3_0_6b").reduced().replace(use_rope=True)
    key = jax.random.PRNGKey(1)
    p = attention.attn_init(key, cfg, jnp.float32)
    b, steps, win = 1, 12, 4

    xs = jax.random.normal(jax.random.fold_in(key, 1), (b, steps, cfg.d_model))
    # ring cache sized exactly `win`
    ring = attention.init_layer_cache(cfg, b, win, jnp.float32)
    # big cache, windowed mask
    full = attention.init_layer_cache(cfg, b, steps + 1, jnp.float32)
    for t in range(steps):
        lengths = jnp.full((b,), t, jnp.int32)
        x = xs[:, t:t + 1]
        o_ring, ring = attention.attn_decode_step(p, cfg, ring, x, lengths, win)
        o_full, full = attention.attn_decode_step(p, cfg, full, x, lengths, win)
        np.testing.assert_allclose(np.asarray(o_ring), np.asarray(o_full),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"step {t}")


def test_mrope_reduces_to_rope_on_text():
    """With all three position components equal (pure text), M-RoPE == RoPE."""
    b, s, h, hd = 2, 6, 2, 32
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (b, s, h, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    pos3 = jnp.broadcast_to(pos[..., None], (b, s, 3))
    r1 = layers.apply_rope(x, pos, 1e4)
    r2 = layers.apply_mrope(x, pos3, 1e4, (6, 5, 5))
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                               rtol=1e-6, atol=1e-6)


def test_qk_norm_applied():
    cfg = get_config("qwen3_0_6b").reduced()
    assert cfg.qk_norm
    key = jax.random.PRNGKey(3)
    p = attention.attn_init(key, cfg, jnp.float32)
    assert "q_norm" in p and "k_norm" in p


def test_int8_kv_cache_decode_close_to_fp():
    """int8 KV cache (per-token-per-head scales): prefill+decode within
    quantization tolerance of the fp path, at half the cache bytes."""
    import numpy as np
    from repro.models.model import build_model
    base = get_config("qwen3_0_6b").reduced()
    b, s = 2, 10
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, base.vocab_size, (b, s)), jnp.int32)

    outs = {}
    for name, cfg in (("fp", base), ("int8", base.replace(kv_cache_dtype="int8"))):
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        cache = m.init_cache(b, 24)
        _, cache = m.prefill(params, {"tokens": toks[:, :-1]}, cache)
        logits, _ = m.decode_step(params, cache, {
            "tokens": toks[:, -1:], "lengths": jnp.full((b,), s - 1, jnp.int32)})
        outs[name] = np.asarray(logits, np.float32)
    # int8 bytes check
    m8 = build_model(base.replace(kv_cache_dtype="int8"))
    spec = m8.cache_specs(b, 24)
    assert spec["k"].dtype == jnp.int8 and "k_scale" in spec
    np.testing.assert_allclose(outs["int8"], outs["fp"], rtol=0.08, atol=0.08)

"""ISSUE 8 tentpole: Pallas paged-attention kernel behind the gather path.

Three layers of guarantees (docs/DESIGN.md §11):

  * **model** — ``forward_routed(paged_kernel=True)`` is token-equivalent
    to the virtual-cache gather path for fp32 and int8 pools, at page
    sizes dividing neither the prompt nor the cache;
  * **engine** — the ``EngineConfig.paged_kernel`` engine generates the
    EXACT greedy token streams of the gather-path engine through the full
    ServingEngine: mixed prefill/decode batches, prefix-cache hits,
    overcommit preempt/restore, and the int8 KV cache — with ZERO extra
    jit traces (the kernel lives inside the one unified program);
  * **reference path** — the satellite fix (dequantize only attended
    slots) is bit-exact against the old dequantize-everything gather.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import attention
from repro.models.model import build_model
from repro.serving.engine import EngineConfig, ServingEngine

MOE_ARCH = "qwen3_moe_30b_a3b"
DENSE_ARCH = "qwen3_0_6b"


def nocap(arch, **kw):
    return get_config(arch).reduced().replace(capacity_factor=8.0, **kw)


def generations(done):
    return {r.uid: list(r.generated) for r in done}


def _engine(cfg, **kw):
    eng_kw = dict(max_batch=2, prefill_len=8, max_cache=32,
                  async_steps=False, chunk_len=3, paged=True, page_size=5)
    eng_kw.update(kw)
    return ServingEngine(cfg, EngineConfig(**eng_kw),
                         rng=jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# model level: kernel path == gather path through forward_routed
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", [MOE_ARCH, DENSE_ARCH])
@pytest.mark.parametrize("kv_dtype", ["native", "int8"])
def test_forward_routed_kernel_matches_gather(arch, kv_dtype):
    """Chunked prefill + decode through forward_routed: the Pallas path's
    greedy argmax must equal the gather path's at every step (page size 5
    divides neither the 8-token prompt nor the 32-slot cache)."""
    cfg = nocap(arch, kv_cache_dtype=kv_dtype)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s, ps, nb = 2, 8, 5, 7
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 100, (b, s)),
                       jnp.int32)
    bt = jnp.asarray(np.arange(b * nb).reshape(b, nb), jnp.int32)
    outs = {}
    for pk in (False, True):
        cache = model.init_paged_cache(b * nb, ps)
        argmaxes = []
        last = None
        for lo in range(0, s, 3):                      # chunked prefill
            hi = min(lo + 3, s)
            logits, cache, _ = model.forward_routed(
                params, {"tokens": toks[:, lo:hi],
                         "lengths": jnp.full((b,), lo, jnp.int32),
                         "seg_lens": jnp.full((b,), hi - lo, jnp.int32),
                         "block_tables": bt}, cache, paged_kernel=pk)
            last = jnp.argmax(logits, -1).astype(jnp.int32)
            argmaxes.append(np.asarray(last))
        for i in range(4):                             # greedy decode
            logits, cache, _ = model.forward_routed(
                params, {"tokens": last[:, None],
                         "lengths": jnp.full((b,), s + i, jnp.int32),
                         "seg_lens": jnp.ones((b,), jnp.int32),
                         "block_tables": bt}, cache, paged_kernel=pk)
            last = jnp.argmax(logits, -1).astype(jnp.int32)
            argmaxes.append(np.asarray(last))
        outs[pk] = argmaxes
    np.testing.assert_array_equal(np.stack(outs[False]),
                                  np.stack(outs[True]))


# ---------------------------------------------------------------------------
# engine level: EXACT token streams, all serving features
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", [MOE_ARCH, DENSE_ARCH])
def test_paged_kernel_engine_matches_gather(arch):
    """Mixed-length prompts with a mid-flight arrival (mixed prefill /
    decode batches): kernel and gather engines must emit identical greedy
    streams, with identical jit trace counts (zero extra traces — the
    kernel lives inside the one unified program, analysis R3)."""
    cfg = nocap(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 100, n) for n in (8, 5, 8, 7)]
    outs, traces = {}, {}
    for pk in (False, True):
        eng = _engine(cfg, paged_kernel=pk)
        eng.submit(prompts[0], max_new_tokens=6)
        eng.step()
        eng.step()
        for p in prompts[1:]:
            eng.submit(p, max_new_tokens=4)
        outs[pk] = generations(eng.run_until_done())
        traces[pk] = dict(eng.trace_counts)
    assert outs[True] == outs[False]
    assert traces[True] == traces[False]


def test_paged_kernel_engine_int8_kv_matches_gather():
    cfg = nocap(MOE_ARCH, kv_cache_dtype="int8")
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 100, n) for n in (7, 5, 9)]
    outs = {}
    for pk in (False, True):
        eng = _engine(cfg, paged_kernel=pk)
        for p in prompts:
            eng.submit(p, max_new_tokens=5)
        outs[pk] = generations(eng.run_until_done())
    assert outs[True] == outs[False]


def test_paged_kernel_prefix_hits_match_gather():
    """Requests sharing a system prompt reuse its pages via the prefix
    cache; the kernel path must attend through those shared pages to the
    same tokens, and the hits must actually fire."""
    cfg = nocap(MOE_ARCH)
    rng = np.random.default_rng(2)
    sysp = rng.integers(0, 100, 6)
    prompts = [np.concatenate([sysp, rng.integers(0, 100, 3)])
               for _ in range(3)]
    outs, stats = {}, {}
    for pk in (False, True):
        eng = _engine(cfg, page_size=4, paged_kernel=pk)
        for p in prompts:
            eng.submit(p, max_new_tokens=5)
        outs[pk] = generations(eng.run_until_done())
        stats[pk] = eng.paged_stats()
    assert outs[True] == outs[False]
    assert stats[True]["prefix_hits"] >= 1
    assert stats[True]["prefix_hit_tokens"] == stats[False]["prefix_hit_tokens"]


def test_paged_kernel_preempt_restore_matches_uncontended():
    """Overcommit on a pool too small for both lifetimes forces a
    mid-decode preempt + prefix-cache restore; the kernel engine's tokens
    must match the uncontended gather engine's (restore re-attends
    through remapped block tables)."""
    cfg = nocap(MOE_ARCH)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 100, 7), rng.integers(0, 100, 5)]

    def serve(eng, priorities):
        uids = [eng.submit(p, max_new_tokens=8, priority=pr)
                for p, pr in zip(prompts, priorities)]
        eng.run_until_done()
        return {i: list(eng._all[u].generated) for i, u in enumerate(uids)}

    eng = _engine(cfg, page_size=4, num_pages=4, overcommit=True,
                  paged_kernel=True)
    got = serve(eng, [0, 5])
    assert eng.resilience_stats()["preemptions"] >= 1
    assert eng.resilience_stats()["restores"] >= 1
    want = serve(_engine(cfg, page_size=4), [0, 0])
    assert got == want


def test_paged_kernel_requires_paged():
    with pytest.raises(ValueError, match="paged_kernel requires paged"):
        ServingEngine(nocap(MOE_ARCH), EngineConfig(
            max_batch=2, prefill_len=8, max_cache=32, paged_kernel=True))


# ---------------------------------------------------------------------------
# satellite fix: attended-slot dequant is bit-exact vs full dequant
# ---------------------------------------------------------------------------

def test_masked_dequant_bit_exact_vs_full_dequant():
    """The gather path now dequantizes only the slots some token attends.
    Against the old dequantize-the-whole-virtual-cache behavior (inlined
    here from the module's own helpers) the outputs of every VALID token
    must be bit-identical — excluded slots' logits are NEG_INF-masked, so
    their (finite) K/V content never reaches the softmax."""
    cfg = nocap(MOE_ARCH, kv_cache_dtype="int8")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda a: a[0], params["blocks"])["attn"]
    b, t, ps, nb, num_pages = 2, 3, 4, 6, 9
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((b, t, cfg.d_model)) * 0.1,
                    jnp.float32)
    lengths = jnp.asarray([6, 2], jnp.int32)
    seg_lens = jnp.asarray([3, 2], jnp.int32)
    positions = lengths[:, None] + jnp.arange(t)[None]
    bt = jnp.asarray(rng.permuted(np.tile(np.arange(num_pages),
                                          (b, 1)), axis=1)[:, :nb],
                     jnp.int32)
    shape = (num_pages, ps, cfg.num_kv_heads, cfg.head_dim)
    # garbage EVERYWHERE the scatter doesn't overwrite: huge scales make
    # any accidental dequant of an unattended slot numerically loud
    cache = {"k": jnp.asarray(rng.integers(-127, 128, shape), jnp.int8),
             "v": jnp.asarray(rng.integers(-127, 128, shape), jnp.int8),
             "k_scale": jnp.asarray(rng.random(shape[:-1] + (1,)) * 1e6,
                                    jnp.float32),
             "v_scale": jnp.asarray(rng.random(shape[:-1] + (1,)) * 1e6,
                                    jnp.float32)}

    out, new_cache = attention.attn_block_step_paged(
        lp, cfg, cache, x, positions, lengths, seg_lens, bt, None)

    # the pre-change computation, step for step
    q, k_new, v_new = attention._project_qkv(lp, cfg, x, positions, None,
                                             None)
    valid = jnp.arange(t)[None, :] < seg_lens[:, None]
    blk = positions // ps
    page = jnp.take_along_axis(bt, jnp.clip(blk, 0, nb - 1), axis=1)
    page = jnp.where(valid & (blk < nb), page, num_pages)
    slot = positions % ps
    kq, ksc = attention.quantize_kv(k_new)
    vq, vsc = attention.quantize_kv(v_new)
    ref_cache = {
        kk: attention._paged_scatter(cache[kk], nn, page, slot)
        for kk, nn in (("k", kq), ("v", vq),
                       ("k_scale", ksc), ("v_scale", vsc))}
    btc = jnp.clip(bt, 0, num_pages - 1)
    gather = lambda pool: jnp.take(pool, btc, axis=0).reshape(
        (b, nb * ps) + pool.shape[2:])
    k_cache = attention.dequantize_kv(gather(ref_cache["k"]),
                                      gather(ref_cache["k_scale"]), x.dtype)
    v_cache = attention.dequantize_kv(gather(ref_cache["v"]),
                                      gather(ref_cache["v_scale"]), x.dtype)
    slot_pos = jnp.arange(nb * ps, dtype=jnp.int32)[None, None, :]
    qp = jnp.where(valid, positions, -1)[:, :, None]
    mask = slot_pos <= qp
    ref_out = attention._attend_grouped_block(cfg, q, k_cache, v_cache, mask)
    ref_out = ref_out.reshape(b, t, cfg.num_heads * cfg.head_dim)
    from repro.core import quant
    ref_out = quant.qdot("bse,ed->bsd", ref_out, lp["wo"])

    for leaf in new_cache:
        np.testing.assert_array_equal(np.asarray(new_cache[leaf]),
                                      np.asarray(ref_cache[leaf]))
    for bi in range(b):
        n = int(seg_lens[bi])
        np.testing.assert_array_equal(np.asarray(out[bi, :n]),
                                      np.asarray(ref_out[bi, :n]))

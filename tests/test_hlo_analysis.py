"""The HLO analyzer (roofline data source) must account loop trip counts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo


def test_scan_flops_trip_multiplied():
    D, L, B = 64, 7, 4
    w = jnp.zeros((L, D, D))
    x = jnp.ones((B, D))

    def f(x, w):
        def body(c, wl):
            return c @ wl, ()
        return jax.lax.scan(body, x, w)[0]

    compiled = jax.jit(f).lower(x, w).compile()
    t = hlo.analyze(compiled.as_text())
    assert t.flops == 2 * B * D * D * L


def test_nested_scan_flops():
    D, Lo, Li = 32, 3, 5
    w = jnp.zeros((Lo, Li, D, D))
    x = jnp.ones((2, D))

    def f(x, w):
        def outer(c, wo):
            def inner(ci, wl):
                return ci @ wl, ()
            return jax.lax.scan(inner, c, wo)[0], ()
        return jax.lax.scan(outer, x, w)[0]

    compiled = jax.jit(f).lower(x, w).compile()
    t = hlo.analyze(compiled.as_text())
    assert t.flops == 2 * 2 * D * D * Lo * Li


def test_plain_matmul_flops_exact():
    for n in (64, 128, 256):
        a = jnp.zeros((n, n), jnp.float32)
        compiled = jax.jit(lambda a, b: a @ b).lower(a, a).compile()
        t = hlo.analyze(compiled.as_text())
        assert t.flops == 2 * n ** 3


def test_bf16_matmul_counts():
    a = jnp.zeros((128, 128), jnp.bfloat16)
    compiled = jax.jit(lambda a, b: (a @ b)).lower(a, a).compile()
    t = hlo.analyze(compiled.as_text())
    assert t.flops == 2 * 128 ** 3


def test_shape_bytes():
    assert hlo.shape_bytes("bf16", "4,8") == 64
    assert hlo.shape_bytes("f32", "") == 4       # scalar
    assert hlo.shape_bytes("pred", "10") == 10


def test_hbm_bytes_less_than_raw():
    D, L = 64, 4
    w = jnp.zeros((L, D, D))
    x = jnp.ones((2, D))

    def f(x, w):
        def body(c, wl):
            return jax.nn.relu(c @ wl) + 1.0, ()
        return jax.lax.scan(body, x, w)[0]

    t = hlo.analyze(jax.jit(f).lower(x, w).compile().as_text())
    assert 0 < t.hbm_bytes <= t.bytes


# ---------------------------------------------------------------------------
# sized_copies: async copy-start/copy-done pairs count once (analysis R1)

_ASYNC_COPY_HLO = """
HloModule async_copy, input_output_alias={ {0}: (0, {}, may-alias) }

ENTRY %main (p0: f32[32]) -> f32[32] {
  %p0 = f32[32]{0} parameter(0)
  %copy-start.1 = (f32[32]{0}, f32[32]{0}, u32[]) copy-start(%p0)
  %cdone.1 = f32[32]{0} copy-done(%copy-start.1)
  %small = f32[4]{0} slice(%cdone.1), slice={[0:4]}
  %small-copy = f32[4]{0} copy(%small)
  ROOT %out = f32[32]{0} copy(%cdone.1)
}
"""


def test_sized_copies_counts_copy_start_once():
    hits = hlo.sized_copies(_ASYNC_COPY_HLO, 128)
    # the async pair bills once (at copy-start, dest = first tuple element)
    # plus the ROOT sync copy; the 16-byte copy is below threshold
    assert len(hits) == 2
    assert all(nb == 128 for _, nb in hits)
    assert any("copy-start" in line for line, _ in hits)
    assert not any("copy-done" in line for line, _ in hits)
    assert set(hlo.sized_copies(_ASYNC_COPY_HLO, 16)) == set(hits) | {
        ("%small-copy = f32[4]{0} copy(%small)", 16)}


def test_sized_copies_real_donation_contrast():
    x = jnp.zeros((64, 64))

    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (0, 0))

    upd = jnp.ones((1, 64))
    undonated = jax.jit(f).lower(x, upd).compile().as_text()
    donated = jax.jit(f, donate_argnums=(0,)).lower(x, upd).compile().as_text()
    full = 64 * 64 * 4
    assert hlo.sized_copies(undonated, full)      # must materialize the buf
    assert not hlo.sized_copies(donated, full)    # in-place via aliasing


# ---------------------------------------------------------------------------
# input_output_alias header parsing (analysis R1)


def test_alias_pairs_parse_header():
    hdr = ("HloModule m, is_scheduled=true, "
           "input_output_alias={ {0}: (3, {}, may-alias), "
           "{1, 0}: (4, {1}, must-alias) }, "
           "entry_computation_layout={(f32[2,2])->f32[2,2]}")
    assert hlo.input_output_alias_pairs(hdr) == [
        hlo.AliasPair((0,), 3, (), "may-alias"),
        hlo.AliasPair((1, 0), 4, (1,), "must-alias"),
    ]
    assert hlo.input_output_aliases(hdr) == 2


def test_alias_pairs_absent_and_empty_index():
    assert hlo.input_output_alias_pairs("HloModule m\n") == []
    assert hlo.input_output_aliases("HloModule m\n") == 0
    hdr = "HloModule m, input_output_alias={ {}: (0, {}, may-alias) }"
    (p,) = hlo.input_output_alias_pairs(hdr)
    assert p.output_index == () and p.param_number == 0


def test_alias_pairs_real_donation():
    x = jnp.zeros((16, 16))
    f = jax.jit(lambda a, b: (a + 1.0, b * 2.0), donate_argnums=(1,))
    pairs = hlo.input_output_alias_pairs(f.lower(x, x).compile().as_text())
    assert any(p.param_number == 1 for p in pairs)


# ---------------------------------------------------------------------------
# collective_ops: async pairs once, dest bytes (analysis R2/R6)

_COLL_HLO = """
HloModule coll

ENTRY %main (x: f32[8,128], y: f32[4,16]) -> f32[16,16] {
  %x = f32[8,128]{1,0} parameter(0)
  %y = f32[4,16]{1,0} parameter(1)
  %ar = f32[8,128]{1,0} all-reduce(%x), replica_groups={}
  %ags = (f32[4,16]{1,0}, f32[16,16]{1,0}) all-gather-start(%y), dimensions={0}
  ROOT %agd = f32[16,16]{1,0} all-gather-done(%ags)
}
"""


def test_collective_ops_bills_async_once_at_dest_size():
    ops = hlo.collective_ops(_COLL_HLO)
    assert [(k, nb) for k, nb, _ in ops] == [
        ("all-reduce", 8 * 128 * 4),
        ("all-gather", 16 * 16 * 4),   # gathered (unsharded) result
    ]


# ---------------------------------------------------------------------------
# breakdown(): trip-count multipliers (hand-written nested while loops)

_NESTED_WHILE_HLO = """
HloModule trip

%inner_cond (pc: (s32[], f32[8,8])) -> pred[] {
  %pc = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%pc), index=0
  %c5 = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c5), direction=LT
}

%inner_body (pb: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %pb = (s32[], f32[8,8]) parameter(0)
  %i2 = s32[] get-tuple-element(%pb), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%pb), index=1
  %y = f32[8,8]{1,0} add(%x, %x)
  %one = s32[] constant(1)
  %ip = s32[] add(%i2, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ip, %y)
}

%outer_cond (qc: (s32[], f32[8,8])) -> pred[] {
  %qc = (s32[], f32[8,8]) parameter(0)
  %j = s32[] get-tuple-element(%qc), index=0
  %c3 = s32[] constant(3)
  ROOT %lt2 = pred[] compare(%j, %c3), direction=LT
}

%outer_body (qb: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %qb = (s32[], f32[8,8]) parameter(0)
  %j2 = s32[] get-tuple-element(%qb), index=0
  %z = f32[8,8]{1,0} get-tuple-element(%qb), index=1
  %zero = s32[] constant(0)
  %it = (s32[], f32[8,8]) tuple(%zero, %z)
  %w = (s32[], f32[8,8]) while(%it), condition=%inner_cond, body=%inner_body
  %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
  %one2 = s32[] constant(1)
  %jp = s32[] add(%j2, %one2)
  ROOT %t2 = (s32[], f32[8,8]) tuple(%jp, %r)
}

ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8]{1,0} parameter(0)
  %i0 = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%i0, %p0)
  %wo = (s32[], f32[8,8]) while(%t0), condition=%outer_cond, body=%outer_body
  ROOT %res = f32[8,8]{1,0} get-tuple-element(%wo), index=1
}
"""


def test_breakdown_nested_while_trip_multiplied():
    top = {k: nb for k, nb, _ in hlo.breakdown(_NESTED_WHILE_HLO, top=50)}
    # inner add: (result + 2 operands) * 8*8*4 B = 768, x (3 outer * 5 inner)
    assert top["add@f32[8,8]"] == 768 * 3 * 5
    # outer scalar add runs 3x, inner one 15x: (4+4+4) * (3 + 15)
    assert top["add@s32[]"] == 12 * (3 + 15)


def test_analyze_nested_while_bytes_trip_multiplied():
    t = hlo.analyze(_NESTED_WHILE_HLO)
    assert t.bytes >= 768 * 3 * 5


def test_breakdown_scan_dot_trip_multiplied():
    D, L, B = 64, 7, 4
    w = jnp.zeros((L, D, D))
    x = jnp.ones((B, D))

    def f(x, w):
        def body(c, wl):
            return c @ wl, ()
        return jax.lax.scan(body, x, w)[0]

    txt = jax.jit(f).lower(x, w).compile().as_text()
    dots = [nb for k, nb, _ in hlo.breakdown(txt, top=100)
            if k.startswith("dot@")]
    # the body dot bills at least its result each iteration, x L trips
    assert dots and max(dots) >= L * B * D * 4


# ---------------------------------------------------------------------------
# breakdown(): fusion-wrapped dynamic-update-slice billed at window size

_FUSED_DUS_HLO = """
HloModule fused_dus

%dus_body (fa: f32[16,64], fb: f32[1,64], fi: s32[]) -> f32[16,64] {
  %fa = f32[16,64]{1,0} parameter(0)
  %fb = f32[1,64]{1,0} parameter(1)
  %fi = s32[] parameter(2)
  ROOT %dus = f32[16,64]{1,0} dynamic-update-slice(%fa, %fb, %fi, %fi)
}

ENTRY %main (a: f32[16,64], b: f32[1,64], i: s32[]) -> f32[16,64] {
  %a = f32[16,64]{1,0} parameter(0)
  %b = f32[1,64]{1,0} parameter(1)
  %i = s32[] parameter(2)
  ROOT %f = f32[16,64]{1,0} fusion(%a, %b, %i), kind=kLoop, calls=%dus_body
}
"""


def test_breakdown_fusion_wrapped_dus_window_billed():
    top = {k: nb for k, nb, _ in hlo.breakdown(_FUSED_DUS_HLO, top=10)}
    full = 16 * 64 * 4
    # in-place update: result+operands minus 2x the full buffer leaves the
    # window read/write (256 B) + index (4 B), never the whole cache
    assert top["fusion@f32[16,64]"] == (2 * full + 256 + 4) - 2 * full
    # the fusion body itself is unreachable from ENTRY via calls/whiles and
    # must not be double-billed
    assert not any(k.startswith("dynamic-update-slice") for k in top)

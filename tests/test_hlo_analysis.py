"""The HLO analyzer (roofline data source) must account loop trip counts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo


def test_scan_flops_trip_multiplied():
    D, L, B = 64, 7, 4
    w = jnp.zeros((L, D, D))
    x = jnp.ones((B, D))

    def f(x, w):
        def body(c, wl):
            return c @ wl, ()
        return jax.lax.scan(body, x, w)[0]

    compiled = jax.jit(f).lower(x, w).compile()
    t = hlo.analyze(compiled.as_text())
    assert t.flops == 2 * B * D * D * L


def test_nested_scan_flops():
    D, Lo, Li = 32, 3, 5
    w = jnp.zeros((Lo, Li, D, D))
    x = jnp.ones((2, D))

    def f(x, w):
        def outer(c, wo):
            def inner(ci, wl):
                return ci @ wl, ()
            return jax.lax.scan(inner, c, wo)[0], ()
        return jax.lax.scan(outer, x, w)[0]

    compiled = jax.jit(f).lower(x, w).compile()
    t = hlo.analyze(compiled.as_text())
    assert t.flops == 2 * 2 * D * D * Lo * Li


def test_plain_matmul_flops_exact():
    for n in (64, 128, 256):
        a = jnp.zeros((n, n), jnp.float32)
        compiled = jax.jit(lambda a, b: a @ b).lower(a, a).compile()
        t = hlo.analyze(compiled.as_text())
        assert t.flops == 2 * n ** 3


def test_bf16_matmul_counts():
    a = jnp.zeros((128, 128), jnp.bfloat16)
    compiled = jax.jit(lambda a, b: (a @ b)).lower(a, a).compile()
    t = hlo.analyze(compiled.as_text())
    assert t.flops == 2 * 128 ** 3


def test_shape_bytes():
    assert hlo.shape_bytes("bf16", "4,8") == 64
    assert hlo.shape_bytes("f32", "") == 4       # scalar
    assert hlo.shape_bytes("pred", "10") == 10


def test_hbm_bytes_less_than_raw():
    D, L = 64, 4
    w = jnp.zeros((L, D, D))
    x = jnp.ones((2, D))

    def f(x, w):
        def body(c, wl):
            return jax.nn.relu(c @ wl) + 1.0, ()
        return jax.lax.scan(body, x, w)[0]

    t = hlo.analyze(jax.jit(f).lower(x, w).compile().as_text())
    assert 0 < t.hbm_bytes <= t.bytes

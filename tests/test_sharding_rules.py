"""Production-mesh PartitionSpec rules, checked against the divisibility
decisions recorded in docs/DESIGN.md §4 — on an AbstractMesh (no devices)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ARCH_IDS, get_config
from repro.launch import sharding
from repro.models.model import build_model

MESH = compat.abstract_mesh((16, 16), ("data", "model"))
MESH_MP = compat.abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def specs_for(arch, mode, mesh=MESH):
    cfg = get_config(arch)
    sds = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
    return cfg, sds, sharding.params_pspec(cfg, mesh, sds, mode=mode)


def test_moe_experts_on_model_axis():
    """The paper's expert parallelism: expert dim sharded over 'model'."""
    for arch in ("qwen3_moe_30b_a3b", "granite_moe_3b_a800m"):
        _, _, sp = specs_for(arch, "serve")
        for w in ("w_gate", "w_up", "w_down"):
            assert sp["blocks"]["experts"][w][1] == "model", (arch, w)
        assert sp["blocks"]["router"] == P(None, None, None)


def test_vocab_sharded_everywhere():
    for arch in ARCH_IDS:
        _, _, sp = specs_for(arch, "serve")
        assert sp["embed"][0] == "model", arch


def test_deepseek_gqa_divisibility():
    """64 q heads divide 16 -> wq sharded; 8 kv heads do not -> serve mode
    shards the flattened Hkv*hd dim instead (perf iteration A5)."""
    _, _, sp = specs_for("deepseek_67b", "serve")
    assert sp["blocks"]["attn"]["wq"][2] == "model"
    assert sp["blocks"]["attn"]["wk"][2] == "model"   # flattened 1024 % 16
    assert sp["blocks"]["attn"]["wo"][1] == "model"


def test_train_mode_adds_fsdp_axis():
    cfg, _, sp = specs_for("qwen2_72b", "train")
    assert sp["blocks"]["attn"]["wq"] == P(None, "data", "model")
    assert sp["blocks"]["mlp"]["w_down"][1] == "model"
    assert sp["blocks"]["mlp"]["w_down"][2] == "data"
    assert sp["embed"] == P("model", "data")


def test_serve_mode_no_fsdp():
    _, _, sp = specs_for("qwen2_72b", "serve")
    assert "data" not in jax.tree.leaves(
        jax.tree.map(lambda s: tuple(a for a in s if a), sp,
                     is_leaf=lambda x: isinstance(x, P)))


def test_mamba_weights_replicated_over_model():
    """130M SSM: 24 heads % 16 != 0 -> replicated over model (DESIGN §4)."""
    _, _, sp = specs_for("mamba2_130m", "serve")
    blk = sp["blocks"]["mamba"]
    for name in ("in_proj", "conv_w", "A_log", "norm", "out_proj"):
        assert "model" not in tuple(a for a in blk[name] if a), name


def test_rglru_channel_sharding():
    """lru_width 2560 % 16 == 0 -> recurrent channels sharded (DESIGN §4)."""
    _, _, sp = specs_for("recurrentgemma_2b", "serve")
    rec = sp["blocks"]["rec"]["mix"]
    assert rec["in_x"][2] == "model"
    assert rec["out"][1] == "model"


def test_qwen2_vl_heads():
    """28 heads % 16 != 0 -> attention q replicated, FFN carries the TP."""
    _, _, sp = specs_for("qwen2_vl_7b", "serve")
    assert sp["blocks"]["attn"]["wq"][2] is None
    assert sp["blocks"]["mlp"]["w_gate"][2] == "model"  # 18944 % 16 == 0


def test_multi_pod_specs_compatible():
    """The same rules produce valid specs on the 512-chip multi-pod mesh
    (the 'pod' axis is a pure data axis — never appears in param specs)."""
    for arch in ("qwen3_moe_30b_a3b", "qwen2_72b"):
        _, _, sp = specs_for(arch, "train", MESH_MP)
        axes = {a for s in jax.tree.leaves(
            sp, is_leaf=lambda x: isinstance(x, P)) for a in s if a}
        assert "pod" not in axes


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_specs_rank_matches_params(arch):
    cfg, sds, sp = specs_for(arch, "train")
    for leaf, spec in zip(jax.tree.leaves(sds),
                          jax.tree.leaves(sp, is_leaf=lambda x: isinstance(x, P))):
        assert len(spec) == leaf.ndim, (arch, leaf.shape, spec)


def test_cache_pspec_modes():
    cfg = get_config("qwen2_72b")
    model = build_model(cfg)
    c_sds = model.cache_specs(128, 32768)
    for mode, dim in (("seq", 2), ("hd", 4), ("none", None)):
        sp = sharding.cache_pspec(cfg.replace(kv_cache_shard=mode), MESH, c_sds)
        got = sp["k"]
        if dim is None:
            assert "model" not in tuple(a for a in got if a)
        else:
            assert got[dim] == "model", (mode, got)
        assert got[1] == ("data",) or got[1] == "data"

"""Serving-engine redesign: batched prefill, async stepping, device-side
routing capture (ISSUE 1 tentpole).

The reference modes live in the engine itself (``EngineConfig`` flags), so
equality tests compare the production path against the legacy seed
behaviour bit-for-bit on the same params.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import router as router_lib
from repro.core.dynamic_load import LRUExpertTracker
from repro.models.model import build_model
from repro.serving.engine import EngineConfig, ServingEngine


MOE_ARCH = "qwen3_moe_30b_a3b"
DENSE_ARCH = "qwen3_0_6b"


def make_engine(arch=MOE_ARCH, seed=0, **eng_kw):
    cfg = get_config(arch).reduced()
    # these tests pin the TWO-PROGRAM reference engine's invariants
    # (batched-vs-sequential prefill, async-vs-sync stepping); the unified
    # token-budget path has its own suite in tests/test_unified_step.py
    kw = dict(max_batch=2, prefill_len=8, max_cache=32, unified_step=False)
    kw.update(eng_kw)
    return ServingEngine(cfg, EngineConfig(**kw), rng=jax.random.PRNGKey(seed))


def submit_all(eng, n_req=3, plen=6, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [eng.submit(rng.integers(0, 100, plen), max_new_tokens=max_new)
            for _ in range(n_req)]


def generations(done):
    return {r.uid: list(r.generated) for r in done}


# ---------------------------------------------------------------------------
# batched prefill == sequential per-request prefill, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", [MOE_ARCH, DENSE_ARCH])
def test_batched_prefill_matches_sequential(arch):
    eng_b = make_engine(arch, batched_prefill=True, async_steps=False)
    eng_s = make_engine(arch, batched_prefill=False, async_steps=False)
    for eng in (eng_b, eng_s):
        submit_all(eng, n_req=3)
    done_b = generations(eng_b.run_until_done())
    done_s = generations(eng_s.run_until_done())
    assert done_b == done_s
    assert all(len(g) == 4 for g in done_b.values())


def test_batched_prefill_preserves_inflight_slots():
    """Admitting into a free slot must not disturb the other slot's cache:
    interleave arrivals so a prefill lands mid-generation."""
    eng_b = make_engine(batched_prefill=True, async_steps=False)
    eng_s = make_engine(batched_prefill=False, async_steps=False)
    outs = {}
    for name, eng in (("b", eng_b), ("s", eng_s)):
        rng = np.random.default_rng(7)
        p1, p2 = rng.integers(0, 100, 6), rng.integers(0, 100, 5)
        eng.submit(p1, max_new_tokens=6)
        eng.step()          # req 1 admitted + 1 decode step
        eng.step()
        eng.submit(p2, max_new_tokens=4)   # arrives mid-flight
        done = eng.run_until_done()
        outs[name] = generations(done)
    assert outs["b"] == outs["s"]


# ---------------------------------------------------------------------------
# async stepping: same tokens, same order, same done accounting as sync
# ---------------------------------------------------------------------------

def test_async_matches_sync_token_for_token():
    eng_a = make_engine(async_steps=True)
    eng_s = make_engine(async_steps=False)
    for eng in (eng_a, eng_s):
        submit_all(eng, n_req=5, max_new=5)   # 5 requests > 2 slots
    done_a = eng_a.run_until_done()
    done_s = eng_s.run_until_done()
    assert generations(done_a) == generations(done_s)
    # completion order is also preserved
    assert [r.uid for r in done_a] == [r.uid for r in done_s]


def test_async_done_accounting_varying_budgets():
    eng = make_engine(async_steps=True)
    rng = np.random.default_rng(3)
    uids, budgets = [], {}
    for i in range(6):
        n = int(rng.integers(2, 7))
        uid = eng.submit(rng.integers(0, 100, 5), max_new_tokens=n)
        uids.append(uid)
        budgets[uid] = n
    done = eng.run_until_done()
    assert sorted(r.uid for r in done) == sorted(uids)
    for r in done:
        assert r.done
        assert len(r.generated) == budgets[r.uid]
        assert all(0 <= t < eng.cfg.vocab_size for t in r.generated)
    # nothing left in flight and no unharvested steps
    assert not eng.queue and all(s is None for s in eng.slots)
    assert not eng._pending


def test_async_defers_harvest_until_completion_boundary():
    """Mid-generation, async mode holds tokens on device (pending buffer
    non-empty, request lists empty) until a completion or flush."""
    eng = make_engine(async_steps=True)
    eng.submit(np.arange(5), max_new_tokens=8)
    eng.step()   # admit (prefill pending) + decode 1
    eng.step()
    req = eng._all[1]
    assert eng._pending, "async mode should buffer device steps"
    assert req.generated == []
    eng.flush()
    assert not eng._pending
    assert len(req.generated) == 3          # prefill token + 2 decode steps


# ---------------------------------------------------------------------------
# device-side routing capture
# ---------------------------------------------------------------------------

def test_device_routing_matches_reference_recompute():
    """Engine tracker stats == an independent replay through the routed
    model API with an identically-grouped fresh tracker."""
    eng = make_engine(max_batch=1, async_steps=False)
    prompt = np.arange(6) % 100
    eng.submit(prompt, max_new_tokens=5)
    done = eng.run_until_done()
    assert len(done) == 1

    cfg = eng.cfg
    model = build_model(cfg)
    ref = LRUExpertTracker(cfg.num_layers, cfg.num_experts)
    cache = model.init_cache(1, eng.ecfg.max_cache)
    pad = np.zeros((eng.ecfg.prefill_len,), np.int32)
    pad[:len(prompt)] = prompt
    logits, cache, routing = model.prefill_routed(
        eng.params, {"tokens": jnp.asarray(pad[None])}, cache)
    routing = np.asarray(routing)
    for layer in range(cfg.num_layers):
        ref.observe(layer, routing[layer])
    ref.tick()
    toks = [int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))]
    lengths = np.array([eng.ecfg.prefill_len], np.int32)
    for _ in range(4):
        logits, cache, routing = model.decode_step_routed(
            eng.params, cache,
            {"tokens": jnp.asarray([[toks[-1]]]),
             "lengths": jnp.asarray(lengths)})
        routing = np.asarray(routing)
        for layer in range(cfg.num_layers):
            ref.observe(layer, routing[layer])
        ref.tick()
        toks.append(int(jnp.argmax(logits[0, -1, :cfg.vocab_size])))
        lengths += 1

    assert done[0].generated == toks
    np.testing.assert_array_equal(eng.tracker.exec_counts, ref.exec_counts)
    np.testing.assert_array_equal(eng.tracker.last_used, ref.last_used)
    e2 = eng.expected_experts_per_node(2)
    assert e2 == ref.mean_executed_per_node(2)
    assert 0.0 < e2 <= cfg.num_experts / 2 + 1e-9


def test_decode_loop_does_zero_host_router_evaluations(monkeypatch):
    """After warmup (jit traces compiled), the steady-state hot loop must
    never call the router on the host — routing stats come exclusively from
    the device aux outputs."""
    eng = make_engine(async_steps=True)
    submit_all(eng, n_req=1, max_new=3)
    eng.run_until_done()   # compiles prefill + decode traces

    def boom(*a, **k):
        raise AssertionError("host-side router evaluation in the hot loop")
    monkeypatch.setattr(router_lib, "route", boom)
    uids = submit_all(eng, n_req=2, max_new=4, seed=11)
    done = eng.run_until_done()
    assert set(uids) <= {r.uid for r in done}
    assert eng.expected_experts_per_node(2) > 0.0


def test_prefill_routing_shape_and_range():
    cfg = get_config(MOE_ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 8
    cache = model.init_cache(b, 16)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 100, (b, s)))
    _, _, routing = model.prefill_routed(params, {"tokens": toks}, cache)
    assert routing.shape == (cfg.num_layers, b * s, cfg.experts_per_token)
    r = np.asarray(routing)
    assert r.min() >= 0 and r.max() < cfg.num_experts
    _, _, dec = model.decode_step_routed(
        params, cache, {"tokens": toks[:, :1],
                        "lengths": jnp.full((b,), s, jnp.int32)})
    assert dec.shape == (cfg.num_layers, b, cfg.experts_per_token)


def test_dense_arch_routing_is_none():
    cfg = get_config(DENSE_ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(1, 16)
    _, _, routing = model.prefill_routed(
        params, {"tokens": jnp.zeros((1, 8), jnp.int32)}, cache)
    assert routing is None

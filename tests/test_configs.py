"""Assigned-architecture configs must match the published specs exactly."""
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, get_config, input_specs

# (layers, d_model, heads, kv, d_ff, vocab, experts, top_k)
ASSIGNED = {
    "musicgen_large":       (48, 2048, 32, 32, 8192, 2048, 0, 0),
    "qwen3_moe_30b_a3b":    (48, 2048, 32, 4, 768, 151936, 128, 8),
    "granite_moe_3b_a800m": (32, 1536, 24, 8, 512, 49155, 40, 8),
    "deepseek_67b":         (95, 8192, 64, 8, 22016, 102400, 0, 0),
    "qwen2_vl_7b":          (28, 3584, 28, 4, 18944, 152064, 0, 0),
    "qwen3_0_6b":           (28, 1024, 16, 8, 3072, 151936, 0, 0),
    "stablelm_12b":         (40, 5120, 32, 8, 13824, 100352, 0, 0),
    "qwen2_72b":            (80, 8192, 64, 8, 29568, 152064, 0, 0),
    "mamba2_130m":          (24, 768, 0, 0, 0, 50280, 0, 0),
    "recurrentgemma_2b":    (26, 2560, 10, 1, 7680, 256000, 0, 0),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assigned_spec(arch):
    L, d, h, kv, ff, v, e, k = ASSIGNED[arch]
    cfg = get_config(arch)
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.num_experts == e
    assert cfg.experts_per_token == k


def test_families():
    fam = {a: get_config(a).family for a in ARCH_IDS}
    assert fam["musicgen_large"] == "audio"
    assert fam["qwen3_moe_30b_a3b"] == "moe"
    assert fam["granite_moe_3b_a800m"] == "moe"
    assert fam["qwen2_vl_7b"] == "vlm"
    assert fam["mamba2_130m"] == "ssm"
    assert fam["recurrentgemma_2b"] == "hybrid"
    assert all(fam[a] == "dense" for a in
               ("deepseek_67b", "qwen3_0_6b", "stablelm_12b", "qwen2_72b"))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_shardability(arch):
    """Production-mesh divisibility: padded experts and padded vocab divide
    the 16-way model axis."""
    cfg = get_config(arch)
    assert cfg.vocab_padded % 16 == 0
    if cfg.is_moe:
        assert cfg.num_experts_padded % 16 == 0
        assert cfg.num_experts_padded >= cfg.num_experts


def test_granite_expert_padding():
    cfg = get_config("granite_moe_3b_a800m")
    assert cfg.num_experts == 40 and cfg.num_experts_padded == 48


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_constraints(arch):
    r = get_config(arch).reduced()
    assert r.num_layers <= 3
    assert r.d_model <= 512
    if r.is_moe:
        assert r.num_experts_padded <= 4
    assert r.family == get_config(arch).family


def test_dbrx_paper_config():
    cfg = get_config("dbrx")
    assert cfg.num_layers == 40
    assert cfg.d_model == 6144
    assert cfg.num_experts == 16 and cfg.experts_per_token == 4


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_cover_all_pairs(arch, shape):
    cfg = get_config(arch)
    specs = input_specs(cfg, SHAPES[shape])
    assert specs, f"no input specs for {arch} x {shape}"
    kind = SHAPES[shape].kind
    if kind == "train":
        assert "labels" in specs
    if kind == "decode":
        assert specs["tokens"].shape[1] == 1
        assert "lengths" in specs
    b = SHAPES[shape].global_batch
    for v in specs.values():
        assert v.shape[0] == b

"""Serving example: batched requests through the engine, reporting the
paper's §5.2 breakdown (prompt evaluation vs token generation) and the
Table 1 routing statistic.

    PYTHONPATH=src python examples/serve_moe.py

Serving knobs (docs/DESIGN.md §3, §5)
-------------------------------------
The engine defaults to the zero-copy production configuration:

* ``EngineConfig.donate_buffers`` (default True) — every hot-loop jit
  donates its cache, and the model updates it in place on a scan carry, so
  the steady-state decode step never copies the KV cache (the paper's C1
  pre-allocated buffers, HLO-verified in tests/test_zero_copy.py).  Set
  False to A/B the copy-per-step baseline.
* ``ModelConfig.gather_decode_max_tk`` (default 64) — small decode batches
  (T·K at or below the threshold) skip the fixed-capacity dispatch and its
  8-slots-per-expert padding floor whenever a capacity-free form is
  cheaper: a per-token expert-weight gather when T·K <= E_local, or a
  one-hot dense compute when T is below the capacity floor; otherwise the
  normal dispatch (with its capacity semantics) still runs.  0 disables.
* ``ModelConfig.expert_parallel="a2a_pipelined"`` +
  ``ModelConfig.ep_microchunks=m`` — on a multi-node mesh, split each
  shard's token block into m chunks and overlap chunk i's expert FFN with
  chunk i+1's all_to_all dispatch (token-exact vs plain ``a2a``;
  single-token decode falls back to ``decentralized``).
* ``EngineConfig.paged`` + ``page_size``/``num_pages`` (docs/DESIGN.md
  §7) — paged KV cache: one donated page pool + per-row block tables
  instead of max_cache slots per request, admission gated on free pages,
  and a radix prefix cache so requests sharing a system prompt skip the
  shared prefill entirely (the demo below passes ``--shared-prefix``-style
  sharing via ``serve_demo(shared_prefix=...)``).

Compare engine modes end-to-end with
``python -m benchmarks.serving_engine`` (writes repo-root
BENCH_serving.json).
"""
from repro.configs.base import get_config
from repro.launch.serve import serve_demo


def main():
    cfg = get_config("qwen3_moe_30b_a3b").reduced()
    print(f"serving {cfg.name} ({cfg.num_experts} experts, "
          f"top-{cfg.experts_per_token})")
    serve_demo(cfg, requests=6, new_tokens=12, prompt_len=24, max_batch=3,
               paged=True, page_size=8, shared_prefix=12)


if __name__ == "__main__":
    main()

"""Serving example: batched requests through the engine, reporting the
paper's §5.2 breakdown (prompt evaluation vs token generation) and the
Table 1 routing statistic.

    PYTHONPATH=src python examples/serve_moe.py
"""
from repro.configs.base import get_config
from repro.launch.serve import serve_demo


def main():
    cfg = get_config("qwen3_moe_30b_a3b").reduced()
    print(f"serving {cfg.name} ({cfg.num_experts} experts, "
          f"top-{cfg.experts_per_token})")
    serve_demo(cfg, requests=6, new_tokens=12, prompt_len=24, max_batch=3)


if __name__ == "__main__":
    main()

"""Expert-parallel scaling demo (paper §5.3 in miniature): run the same MoE
forward under 1/2/4/8-way expert parallelism on host devices and verify the
outputs agree while per-shard expert work shrinks.

    PYTHONPATH=src python examples/expert_parallel_scaling.py
(uses XLA host-device emulation; run standalone, not under the test runner)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import expert_parallel, router as router_lib
from repro.core.dynamic_load import simulate_expected_experts


def main():
    # reduced dims but the paper's true 16-expert arithmetic so 8-way EP divides
    cfg = get_config("dbrx").reduced().replace(
        capacity_factor=8.0, num_experts=16, num_experts_padded=16,
        experts_per_token=4)
    key = jax.random.PRNGKey(0)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts_padded
    layer_p = {
        "router": jax.random.normal(key, (d, e)) * 0.1,
        "experts": {
            "w_gate": jax.random.normal(jax.random.fold_in(key, 1), (e, d, f)) * 0.05,
            "w_up": jax.random.normal(jax.random.fold_in(key, 2), (e, d, f)) * 0.05,
            "w_down": jax.random.normal(jax.random.fold_in(key, 3), (e, f, d)) * 0.05,
        },
    }
    x = jax.random.normal(jax.random.fold_in(key, 4), (4, 16, d))

    ref = None
    for n_model in (1, 2, 4, 8):
        if n_model == 1:
            y, aux, _ = expert_parallel.moe_layer(cfg, None, layer_p, x)
        else:
            mesh = jax.make_mesh((8 // n_model, n_model), ("data", "model"))
            y, aux, _ = expert_parallel.moe_layer(cfg, mesh, layer_p, x)
        y = np.asarray(y, np.float32)
        if ref is None:
            ref = y
        err = np.max(np.abs(y - ref))
        print(f"EP={n_model}: experts/shard={e // n_model:2d} "
              f"maxerr vs 1-way={err:.2e}")

    print("\nE[#exec experts/node/layer] (paper Table 1 statistic, "
          "uniform routing):")
    for n in (2, 3, 4):
        v = simulate_expected_experts(16, 4, n, n_tokens=400)
        print(f"  {n} nodes: {v:.2f}   (paper measured: "
              f"{ {2: 2.65, 3: 2.32, 4: 1.57}[n] })")


if __name__ == "__main__":
    main()

"""End-to-end training driver: a ~100M-parameter MoE (the paper's DBRX
family at laptop scale) trained for a few hundred steps on synthetic data.

    PYTHONPATH=src python examples/train_moe.py [--steps 300]
"""
import argparse

from repro.configs.base import get_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_moe_100m.npz")
    args = ap.parse_args()

    # ~100M-param MoE in the DBRX family: 8 layers, d=512, 16 experts top-4
    cfg = get_config("dbrx").replace(
        name="dbrx-100m",
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
        d_ff=512, vocab_size=8192,
        num_experts=16, num_experts_padded=16, experts_per_token=4,
        dtype="float32", param_dtype="float32", remat=False,
        moe_strategy="dispatch", expert_parallel="decentralized",
    )
    from repro.models.model import build_model  # param count report
    import jax
    n = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))))
    print(f"training {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    params, history = train(cfg, steps=args.steps, global_batch=args.batch,
                            seq_len=args.seq, lr=1e-3, log_every=20,
                            ckpt_path=args.ckpt)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'improved ✓' if last < first else 'NOT improved ✗'})")


if __name__ == "__main__":
    main()

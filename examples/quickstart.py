"""Quickstart: build a reduced MoE model, run the paper's three execution
strategies, and compare their cost profile.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import perf_model
from repro.models.model import build_model


def main():
    # the paper's model (DBRX: 16 experts, top-4) at smoke scale
    cfg = get_config("dbrx").reduced()
    print(f"arch={cfg.name} family={cfg.family} experts={cfg.num_experts} "
          f"top_k={cfg.experts_per_token}")

    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                              jnp.int32),
    }

    # paper §5.2 strategy matrix: Naive / P-L_B / P-L_R-D
    strategies = {
        "naive":   dict(prestack=False, moe_strategy="dispatch",
                        expert_parallel="centralized"),
        "P-L_B":   dict(prestack=True, moe_strategy="dense",
                        expert_parallel="centralized"),
        "P-L_R-D": dict(prestack=True, moe_strategy="dispatch",
                        expert_parallel="decentralized"),
    }
    outs = {}
    for name, kw in strategies.items():
        model = build_model(cfg.replace(capacity_factor=8.0, **kw))
        params = model.init(jax.random.PRNGKey(0))
        logits, aux = model.forward(params, batch)
        loss, _ = model.loss(params, batch)
        outs[name] = np.asarray(logits, np.float32)
        print(f"{name:8s} loss={float(loss):.4f} "
              f"logits[0,0,:3]={np.asarray(logits[0, 0, :3])}")

    # all strategies compute the same function (cost differs, math does not)
    np.testing.assert_allclose(outs["naive"], outs["P-L_R-D"], rtol=2e-3,
                               atol=2e-3)
    print("strategies agree numerically ✓")

    # the paper's performance model, reproducing Table 6
    print("\npaper Table 6 (DBRX on 2–8 Mac Studios, 10 GbE):")
    for row in perf_model.scaling_table():
        print(f"  {row['nodes']} nodes: bound {row['bound_s']*1e3:6.1f} ms/tok"
              f" -> {row['tokens_per_sec_table6']:.1f} tok/s")


if __name__ == "__main__":
    main()

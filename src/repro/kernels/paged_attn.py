"""Pallas TPU kernel: paged attention over the block-table page pool.

Since PR 4 the paged engine has attended by GATHERING each row's pages
into a (B, NB·ps, Hkv, hd) virtual cache — a pool-scale read+write every
step that dominates once pools grow to production size (ROADMAP item 1).
This kernel walks the block table instead: the grid's innermost axis
iterates a row's pages, each (ps, hd) K/V tile is DMA'd straight from the
donated pool into VMEM, and a flash-style online softmax accumulates the
output page by page.  Attention bytes then scale with ``lengths[b]``, not
pool size, and no virtual cache ever exists on either the decode (T=1) or
chunked-prefill (T>1) path.

  grid = (B, Hkv, TG/bq, NB)   — pages innermost, VMEM scratch carry
  q    : (B, Hkv, TG, hd) block (1, 1, bq, hd); row r = (token r//G,
         group r%G), i.e. the G query heads of one kv head interleaved
         per token (grouped GQA without a gqa_repeat materialization)
  k/v  : pool (P, ps, Hkv, hd) block (1, ps, 1, hd); the index map reads
         ``block_tables`` from SMEM (scalar prefetch) to pick the page
  out  : (B, Hkv, TG, hd) block (1, 1, bq, hd), written on the last page

Block-table entries past a row's live length are clamped to the row's
last valid index in the index map, so Pallas's revisit-elision skips the
DMA entirely (same page index twice = no copy) and the position mask
guarantees correctness regardless of what the tile holds.  int8 KV pools
ship their sibling fp32 scale leaves as two extra inputs and dequantize
the (ps, hd) tile in VMEM, the way moe_gemm's quant kernel does for
expert weights.

Validated against kernels/ref.py::paged_attention_ref in interpret mode
on CPU; TPU is the deployment target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, len_ref, seg_ref, q_ref, k_ref, v_ref, *rest,
            nb: int, ps: int, g: int, bq: int, t: int, scale: float,
            window: int | None, quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    qi = pl.program_id(2)
    kstep = pl.program_id(3)

    @pl.when(kstep == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # pages past every live position contribute nothing: skip the math
    # (their DMA was already elided by the clamped index map)
    @pl.when(kstep * ps <= len_ref[b] + t - 1)
    def _accumulate():
        q = q_ref[0, 0]                             # (bq, hd)
        k = k_ref[0, :, 0, :]                       # (ps, hd)
        v = v_ref[0, :, 0, :]
        if quantized:
            # in-VMEM dequant from the sibling scale tiles; cast to the
            # q dtype so logits match the gather path's dequantize_kv bit
            # for bit
            k = (k.astype(jnp.float32) * ks_ref[0, :, 0, :]).astype(q.dtype)
            v = (v.astype(jnp.float32) * vs_ref[0, :, 0, :]).astype(q.dtype)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, ps)

        # q row r attends as token r//g at absolute position len + r//g;
        # rows of padded/invalid tokens (t_idx >= seg) mask everything
        row = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, ps), 0)
        t_idx = row // g
        slot = kstep * ps + jax.lax.broadcasted_iota(jnp.int32, (bq, ps), 1)
        qp = jnp.where(t_idx < seg_ref[b], len_ref[b] + t_idx, -1)
        mask = slot <= qp
        if window is not None:
            mask = mask & (slot > qp - window)
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]                         # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)                 # (bq, ps)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)              # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kstep == nb - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _page_index(i, bt_ref, len_ref, b, *, nb: int, ps: int, t: int,
                num_pages: int):
    """Pool page for grid page-step ``i`` of row ``b``, clamped so every
    step past the row's live extent re-reads the last live page (Pallas
    elides the unchanged DMA).  Table entries are clamped to the pool the
    way the gather path clips: OOB-sentinel writes never reach the table,
    but unallocated blocks hold 0 and a hostile table must not index out
    of the pool."""
    last = jnp.maximum(len_ref[b] + t - 1, 0) // ps
    i_eff = jnp.minimum(i, jnp.minimum(last, nb - 1))
    return jnp.clip(bt_ref[b, i_eff], 0, num_pages - 1)


@functools.partial(jax.jit, static_argnames=("window", "block_q",
                                             "interpret"))
def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array,
                    seg_lens: jax.Array, *, k_scale: jax.Array | None = None,
                    v_scale: jax.Array | None = None,
                    window: int | None = None, block_q: int = 128,
                    interpret: bool = False) -> jax.Array:
    """Paged flash attention: q (B, T, Hq, hd) + pool (P, ps, Hkv, hd)
    + block_tables (B, NB) -> (B, T, Hq, hd).

    Token t of row b sits at absolute position ``lengths[b] + t`` and
    attends every pool slot holding positions <= its own (causal over the
    block table), optionally windowed; tokens with ``t >= seg_lens[b]``
    are padding and get a zero output row.  ``k_scale``/``v_scale`` are
    the int8 pool's sibling fp32 scale leaves (P, ps, Hkv, 1)."""
    b, t, hq, hd = q.shape
    num_pages, ps, hkv, _ = k_pool.shape
    nb = block_tables.shape[1]
    g = hq // hkv
    tg = t * g
    bq = min(block_q, tg)
    tgp = -(-tg // bq) * bq
    n_q = tgp // bq
    # (B,T,Hq,hd) -> (B,T,Hkv,G,hd) -> (B,Hkv,TG,hd): kernel row r is
    # (token r//G, q-head group r%G) of kv head h
    qr = q.reshape(b, t, hkv, g, hd).transpose(0, 2, 1, 3, 4)
    qr = qr.reshape(b, hkv, tg, hd)
    if tgp != tg:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, tgp - tg), (0, 0)))

    quantized = k_scale is not None
    idx = functools.partial(_page_index, nb=nb, ps=ps, t=t,
                            num_pages=num_pages)
    q_spec = pl.BlockSpec(
        (1, 1, bq, hd), lambda bi, h, qi, ki, bt, ln, sg: (bi, h, qi, 0))
    pool_spec = pl.BlockSpec(
        (1, ps, 1, hd),
        lambda bi, h, qi, ki, bt, ln, sg: (idx(ki, bt, ln, bi), 0, h, 0))
    in_specs = [q_spec, pool_spec, pool_spec]
    inputs = [qr, k_pool, v_pool]
    if quantized:
        scale_spec = pl.BlockSpec(
            (1, ps, 1, 1),
            lambda bi, h, qi, ki, bt, ln, sg: (idx(ki, bt, ln, bi), 0, h, 0))
        in_specs += [scale_spec, scale_spec]
        inputs += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, n_q, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, bq, hd), lambda bi, h, qi, ki, bt, ln, sg: (bi, h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, nb=nb, ps=ps, g=g, bq=bq, t=t,
                          scale=hd ** -0.5, window=window,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, tgp, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      seg_lens.astype(jnp.int32), *inputs)
    out = out[:, :, :tg].reshape(b, hkv, t, g, hd).transpose(0, 2, 1, 3, 4)
    return out.reshape(b, t, hq, hd)

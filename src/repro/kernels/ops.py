"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode so the whole
framework remains runnable/testable; on TPU the same call sites compile the
real kernels.  ``interpret`` is resolved from the backend at trace time.
"""
from __future__ import annotations

import jax

from repro.core import quant
from repro.kernels import flash_attn, moe_gemm, ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def moe_ffn(x: jax.Array, w_gate, w_up, w_down) -> jax.Array:
    """Prestacked grouped expert FFN (E, C, D) -> (E, C, D).

    Weights may be raw arrays or blockwise-quantized QuantTensors
    (docs/DESIGN.md §8) — the quantized variant streams int8/packed-int4
    tiles HBM->VMEM and dequantizes in-kernel."""
    if isinstance(w_gate, quant.QuantTensor):
        return moe_gemm.moe_ffn_kernel_quant(x, w_gate, w_up, w_down,
                                             interpret=_interpret())
    return moe_gemm.moe_ffn_kernel(x, w_gate, w_up, w_down,
                                   interpret=_interpret())


moe_ffn_ref = ref.moe_ffn_ref
moe_ffn_ref_quant = ref.moe_ffn_ref_quant


def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=256, block_k=512):
    """Flash attention (B, H, S, hd) -> (B, H, S, hd)."""
    return flash_attn.flash_attention(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k,
                                      interpret=_interpret())


flash_attention_ref = ref.flash_attention_ref

"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode so the whole
framework remains runnable/testable; on TPU the same call sites compile the
real kernels.  ``interpret`` is resolved from the backend at trace time.
"""
from __future__ import annotations

import jax

from repro.kernels import flash_attn, moe_gemm, ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def moe_ffn(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
            w_down: jax.Array) -> jax.Array:
    """Prestacked grouped expert FFN (E, C, D) -> (E, C, D)."""
    return moe_gemm.moe_ffn_kernel(x, w_gate, w_up, w_down,
                                   interpret=_interpret())


moe_ffn_ref = ref.moe_ffn_ref


def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=256, block_k=512):
    """Flash attention (B, H, S, hd) -> (B, H, S, hd)."""
    return flash_attn.flash_attention(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k,
                                      interpret=_interpret())


flash_attention_ref = ref.flash_attention_ref

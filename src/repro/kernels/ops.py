"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode so the whole
framework remains runnable/testable; on TPU the same call sites compile the
real kernels.  ``interpret`` is resolved from the backend at trace time.
"""
from __future__ import annotations

import jax

from repro.core import quant
from repro.kernels import flash_attn, moe_gemm, paged_attn, ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def moe_ffn(x: jax.Array, w_gate, w_up, w_down) -> jax.Array:
    """Prestacked grouped expert FFN (E, C, D) -> (E, C, D).

    Weights may be raw arrays or blockwise-quantized QuantTensors
    (docs/DESIGN.md §8) — the quantized variant streams int8/packed-int4
    tiles HBM->VMEM and dequantizes in-kernel."""
    if isinstance(w_gate, quant.QuantTensor):
        return moe_gemm.moe_ffn_kernel_quant(x, w_gate, w_up, w_down,
                                             interpret=_interpret())
    return moe_gemm.moe_ffn_kernel(x, w_gate, w_up, w_down,
                                   interpret=_interpret())


moe_ffn_ref = ref.moe_ffn_ref
moe_ffn_ref_quant = ref.moe_ffn_ref_quant


def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=256, block_k=512):
    """Flash attention (B, H, S, hd) -> (B, H, S, hd)."""
    return flash_attn.flash_attention(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k,
                                      interpret=_interpret())


flash_attention_ref = ref.flash_attention_ref


def paged_attention(q, cache, block_tables, lengths, seg_lens, *,
                    window=None, block_q=128):
    """Block-table paged attention straight off the page-pool cache dict
    (models/attention.paged_layer_cache_spec leaves) — decode (T=1) and
    chunked prefill (T>1) share one kernel.  int8 pools dispatch the
    in-kernel-dequant variant off their sibling scale leaves."""
    return paged_attn.paged_attention(
        q, cache["k"], cache["v"], block_tables, lengths, seg_lens,
        k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"),
        window=window, block_q=block_q, interpret=_interpret())


paged_attention_ref = ref.paged_attention_ref

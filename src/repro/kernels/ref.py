"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """Dense masked attention oracle. q/k/v: (B, H, S, hd) -> (B, H, S, hd)."""
    s = q.shape[2]
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), jnp.bool_)
    if causal:
        mask = mask & (kp <= qp)
    if window is not None:
        mask = mask & (kp > qp - window)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        block_tables: jax.Array, lengths: jax.Array,
                        seg_lens: jax.Array, *, k_scale=None, v_scale=None,
                        window: int | None = None) -> jax.Array:
    """Page-walk oracle for kernels/paged_attn.py (fp + int8 pools).

    Walks each row's block table page by page, concatenates the pages
    into that row's linear cache view (virtual slot s = absolute position
    s), then runs dense masked grouped-GQA attention.  Written as the
    flash recurrence collapsed to one step so fully-masked (padding) rows
    come out exactly zero, like the kernel."""
    b, t, hq, hd = q.shape
    num_pages, ps, hkv, _ = k_pool.shape
    nb = block_tables.shape[1]
    g = hq // hkv

    def walk(pool, scale):
        pages = [jnp.take(pool, jnp.clip(block_tables[:, i], 0,
                                         num_pages - 1), axis=0)
                 for i in range(nb)]                  # each (B, ps, Hkv, ·)
        lin = jnp.concatenate(pages, axis=1)          # (B, S, Hkv, ·)
        if scale is not None:
            spages = [jnp.take(scale, jnp.clip(block_tables[:, i], 0,
                                               num_pages - 1), axis=0)
                      for i in range(nb)]
            lin = (lin.astype(jnp.float32)
                   * jnp.concatenate(spages, axis=1)).astype(q.dtype)
        return lin

    k = walk(k_pool, k_scale)
    v = walk(v_pool, v_scale)
    qg = q.reshape(b, t, hkv, g, hd)
    logits = jnp.einsum("bqhgd,bshd->bhgqs", qg, k,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    slot = jnp.arange(nb * ps)[None, None, :]
    qp = jnp.where(jnp.arange(t)[None, :] < seg_lens[:, None],
                   lengths[:, None] + jnp.arange(t), -1)[:, :, None]
    mask = slot <= qp
    if window is not None:
        mask = mask & (slot > qp - window)
    mask5 = mask[:, None, None, :, :]                 # (B,1,1,T,S)
    logits = jnp.where(mask5, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.where(mask5, jnp.exp(logits - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgqs,bshd->bhgqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(l, 1e-30)
    return (out.transpose(0, 3, 1, 2, 4)
            .reshape(b, t, hq, hd).astype(q.dtype))


def moe_ffn_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                w_down: jax.Array) -> jax.Array:
    """Grouped SwiGLU expert FFN. x: (E, C, D) -> (E, C, D)."""
    g = jnp.einsum("ecd,edf->ecf", x, w_gate,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", x, w_up,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w_down,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def moe_ffn_ref_quant(x: jax.Array, w_gate, w_up, w_down) -> jax.Array:
    """Oracle for the quantized grouped GEMM (kernels/moe_gemm.py
    ``moe_ffn_kernel_quant``): dequantize the QuantTensor weights to the
    activation dtype, then run the dense reference — the in-kernel tile
    dequant must match this within the usual kernel tolerances."""
    from repro.core import quant
    m = lambda w: quant.materialize(w, x.dtype)
    return moe_ffn_ref(x, m(w_gate), m(w_up), m(w_down))

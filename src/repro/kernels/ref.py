"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """Dense masked attention oracle. q/k/v: (B, H, S, hd) -> (B, H, S, hd)."""
    s = q.shape[2]
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), jnp.bool_)
    if causal:
        mask = mask & (kp <= qp)
    if window is not None:
        mask = mask & (kp > qp - window)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def moe_ffn_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                w_down: jax.Array) -> jax.Array:
    """Grouped SwiGLU expert FFN. x: (E, C, D) -> (E, C, D)."""
    g = jnp.einsum("ecd,edf->ecf", x, w_gate,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", x, w_up,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w_down,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def moe_ffn_ref_quant(x: jax.Array, w_gate, w_up, w_down) -> jax.Array:
    """Oracle for the quantized grouped GEMM (kernels/moe_gemm.py
    ``moe_ffn_kernel_quant``): dequantize the QuantTensor weights to the
    activation dtype, then run the dense reference — the in-kernel tile
    dequant must match this within the usual kernel tolerances."""
    from repro.core import quant
    m = lambda w: quant.materialize(w, x.dtype)
    return moe_ffn_ref(x, m(w_gate), m(w_up), m(w_down))

"""Pallas TPU kernel: prestacked grouped expert FFN (SwiGLU).

The paper's prestacking (C2) made expert weights one contiguous array so the
runtime never re-prepares them; on TPU the same layout lets a single kernel
stream every expert's tiles HBM->VMEM with no per-expert dispatch.  This
kernel fuses the whole expert FFN  y = (silu(x Wg) * (x Wu)) Wd  for a batch
of experts:

  grid = (E, C/bc, F/bf)   — f innermost, accumulating into a VMEM scratch
  x   : (E, C, D)  block (1, bc, D)
  Wg/Wu: (E, D, F) block (1, D, bf)       } MXU-aligned tiles
  Wd  : (E, F, D)  block (1, bf, D)
  out : (E, C, D)  block (1, bc, D), written on the last f step

VMEM working set (bc=128, bf=256, D=2048, bf16):
  x 0.5 MB + Wg/Wu 2x1 MB + Wd 1 MB + fp32 acc 1 MB ~= 4.5 MB  << 16 MB.

Validated against kernels/ref.py in interpret mode (CPU) over a
shape/dtype sweep; TPU is the deployment target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quant


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *, n_f: int):
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                   # (bc, D)
    g = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)       # (bc, bf)
    acc_ref[...] += jnp.dot(h, wd_ref[0], preferred_element_type=jnp.float32)

    @pl.when(f == n_f - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "interpret"))
def moe_ffn_kernel(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                   w_down: jax.Array, *, block_c: int = 128,
                   block_f: int = 256, interpret: bool = False) -> jax.Array:
    """x: (E, C, D); w_gate/w_up: (E, D, F); w_down: (E, F, D) -> (E, C, D).

    C and F are padded up to the block sizes (zero padding is exact for this
    FFN: silu(0)*0 = 0 and zero Wd rows contribute nothing).
    """
    e, c, d = x.shape
    f = w_gate.shape[-1]
    bc, bf = min(block_c, c), min(block_f, f)
    cp = (c + bc - 1) // bc * bc
    fp = (f + bf - 1) // bf * bf
    if cp != c:
        x = jnp.pad(x, ((0, 0), (0, cp - c), (0, 0)))
    if fp != f:
        w_gate = jnp.pad(w_gate, ((0, 0), (0, 0), (0, fp - f)))
        w_up = jnp.pad(w_up, ((0, 0), (0, 0), (0, fp - f)))
        w_down = jnp.pad(w_down, ((0, 0), (0, fp - f), (0, 0)))
    n_c, n_f = cp // bc, fp // bf

    out = pl.pallas_call(
        functools.partial(_kernel, n_f=n_f),
        grid=(e, n_c, n_f),
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda e_, c_, f_: (e_, c_, 0)),
            pl.BlockSpec((1, d, bf), lambda e_, c_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, d, bf), lambda e_, c_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, bf, d), lambda e_, c_, f_: (e_, f_, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda e_, c_, f_: (e_, c_, 0)),
        out_shape=jax.ShapeDtypeStruct((e, cp, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, d), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
    return out[:, :c, :]


# ---------------------------------------------------------------------------
# quantized variant: in-VMEM dequantization of blockwise int8/int4 weights
# ---------------------------------------------------------------------------

def _dequant_tile(v, s, bits: int, qb: int, rows: int):
    """Dequantize one weight tile inside the kernel.

    v: (Kp, N) int8 payload tile (packed pairs along axis 0 for int4);
    s: (nb, N) fp32 per-block scales; ``rows`` is the tile's logical K.
    Nibble unpack is shifts/compares and the scale expansion a static
    repeat — both lower on TPU without extra HBM traffic: the tile was
    fetched quantized (1 or 0.5 bytes/value) and widens to fp32 in VMEM
    only, which is the whole point of the quantized store (the HBM read
    per expert tile shrinks 2-4x vs bf16)."""
    if bits == 4:
        v = quant.unpack_int4(v, axis=0)
    v = v[:rows]
    sf = jnp.repeat(s, qb, axis=0)[:rows]
    return v.astype(jnp.float32) * sf


def _kernel_q(x_ref, wg_ref, wgs_ref, wu_ref, wus_ref, wd_ref, wds_ref,
              o_ref, acc_ref, *, n_f: int, bits: int, qb: int, d: int,
              bf: int):
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                   # (bc, D)
    wg = _dequant_tile(wg_ref[0], wgs_ref[0], bits, qb, d)   # (D, bf)
    wu = _dequant_tile(wu_ref[0], wus_ref[0], bits, qb, d)
    g = jnp.dot(x, wg.astype(x.dtype), preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu.astype(x.dtype), preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)       # (bc, bf)
    wd = _dequant_tile(wd_ref[0], wds_ref[0], bits, qb, bf)  # (bf, D)
    acc_ref[...] += jnp.dot(h, wd.astype(x.dtype),
                            preferred_element_type=jnp.float32)

    @pl.when(f == n_f - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _pad_dim(a, size: int, axis: int):
    if a.shape[axis] == size:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, size - a.shape[axis])
    return jnp.pad(a, pad)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "interpret"))
def moe_ffn_kernel_quant(x: jax.Array, w_gate: quant.QuantTensor,
                         w_up: quant.QuantTensor, w_down: quant.QuantTensor,
                         *, block_c: int = 128, block_f: int = 256,
                         interpret: bool = False) -> jax.Array:
    """Grouped SwiGLU FFN over blockwise-quantized expert weights.

    x: (E, C, D) fp; w_gate/w_up: QuantTensor (E, D, F) quantized along D;
    w_down: QuantTensor (E, F, D) quantized along F — the layout
    ``core/quant.quantize_tree`` produces for the prestacked expert stack.
    Same grid as ``moe_ffn_kernel`` (E, C/bc, F/bf), but each weight tile
    arrives in VMEM as int8/packed-int4 payload + fp32 block scales and is
    dequantized in-kernel (``_dequant_tile``): HBM streams the compressed
    bytes, the MXU sees fp tiles.  The f-tile width is clamped to a
    multiple of the quantization block so scale tiles stay aligned; F is
    zero-padded to whole tiles (exact: zero scales dequantize to zero and
    silu(0)*0 contributes nothing).  Validated against kernels/ref.py in
    interpret mode (tests/test_kernels.py); TPU is the deployment target.
    """
    e, c, d = x.shape
    f = w_gate.shape[-1]
    bits, qb = w_gate.bits, w_gate.block
    assert (w_up.bits, w_up.block) == (bits, qb), "mixed quant params"
    assert (w_down.bits, w_down.block) == (bits, qb), "mixed quant params"
    bc = min(block_c, c)
    cp = (c + bc - 1) // bc * bc
    if cp != c:
        x = jnp.pad(x, ((0, 0), (0, cp - c), (0, 0)))
    # f-tile width: a multiple of the quant block (so every wd scale tile
    # is whole blocks), covering F padded up to whole quant blocks
    fq = -(-f // qb) * qb
    bf = max(min(block_f, fq) // qb * qb, qb)
    fp = -(-fq // bf) * bf
    n_c, n_f = cp // bc, fp // bf

    wg_d = _pad_dim(w_gate.data, fp, 2)            # (E, Dp, Fp)
    wu_d = _pad_dim(w_up.data, fp, 2)
    wg_s = _pad_dim(w_gate.scale, fp, 2)           # (E, nb_d, Fp)
    wu_s = _pad_dim(w_up.scale, fp, 2)
    rows = fp // 2 if bits == 4 else fp
    wd_d = _pad_dim(w_down.data, rows, 1)          # (E, Fp[/2], D)
    wd_s = _pad_dim(w_down.scale, fp // qb, 1)     # (E, Fp/qb, D)
    dp, nb_d = wg_d.shape[1], wg_s.shape[1]
    bf_rows = bf // 2 if bits == 4 else bf

    out = pl.pallas_call(
        functools.partial(_kernel_q, n_f=n_f, bits=bits, qb=qb, d=d, bf=bf),
        grid=(e, n_c, n_f),
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda e_, c_, f_: (e_, c_, 0)),
            pl.BlockSpec((1, dp, bf), lambda e_, c_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, nb_d, bf), lambda e_, c_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, dp, bf), lambda e_, c_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, nb_d, bf), lambda e_, c_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, bf_rows, d), lambda e_, c_, f_: (e_, f_, 0)),
            pl.BlockSpec((1, bf // qb, d), lambda e_, c_, f_: (e_, f_, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda e_, c_, f_: (e_, c_, 0)),
        out_shape=jax.ShapeDtypeStruct((e, cp, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, d), jnp.float32)],
        interpret=interpret,
    )(x, wg_d, wg_s, wu_d, wu_s, wd_d, wd_s)
    return out[:, :c, :]

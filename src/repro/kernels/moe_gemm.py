"""Pallas TPU kernel: prestacked grouped expert FFN (SwiGLU).

The paper's prestacking (C2) made expert weights one contiguous array so the
runtime never re-prepares them; on TPU the same layout lets a single kernel
stream every expert's tiles HBM->VMEM with no per-expert dispatch.  This
kernel fuses the whole expert FFN  y = (silu(x Wg) * (x Wu)) Wd  for a batch
of experts:

  grid = (E, C/bc, F/bf)   — f innermost, accumulating into a VMEM scratch
  x   : (E, C, D)  block (1, bc, D)
  Wg/Wu: (E, D, F) block (1, D, bf)       } MXU-aligned tiles
  Wd  : (E, F, D)  block (1, bf, D)
  out : (E, C, D)  block (1, bc, D), written on the last f step

VMEM working set (bc=128, bf=256, D=2048, bf16):
  x 0.5 MB + Wg/Wu 2x1 MB + Wd 1 MB + fp32 acc 1 MB ~= 4.5 MB  << 16 MB.

Validated against kernels/ref.py in interpret mode (CPU) over a
shape/dtype sweep; TPU is the deployment target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *, n_f: int):
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                   # (bc, D)
    g = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)       # (bc, bf)
    acc_ref[...] += jnp.dot(h, wd_ref[0], preferred_element_type=jnp.float32)

    @pl.when(f == n_f - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "interpret"))
def moe_ffn_kernel(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                   w_down: jax.Array, *, block_c: int = 128,
                   block_f: int = 256, interpret: bool = False) -> jax.Array:
    """x: (E, C, D); w_gate/w_up: (E, D, F); w_down: (E, F, D) -> (E, C, D).

    C and F are padded up to the block sizes (zero padding is exact for this
    FFN: silu(0)*0 = 0 and zero Wd rows contribute nothing).
    """
    e, c, d = x.shape
    f = w_gate.shape[-1]
    bc, bf = min(block_c, c), min(block_f, f)
    cp = (c + bc - 1) // bc * bc
    fp = (f + bf - 1) // bf * bf
    if cp != c:
        x = jnp.pad(x, ((0, 0), (0, cp - c), (0, 0)))
    if fp != f:
        w_gate = jnp.pad(w_gate, ((0, 0), (0, 0), (0, fp - f)))
        w_up = jnp.pad(w_up, ((0, 0), (0, 0), (0, fp - f)))
        w_down = jnp.pad(w_down, ((0, 0), (0, fp - f), (0, 0)))
    n_c, n_f = cp // bc, fp // bf

    out = pl.pallas_call(
        functools.partial(_kernel, n_f=n_f),
        grid=(e, n_c, n_f),
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda e_, c_, f_: (e_, c_, 0)),
            pl.BlockSpec((1, d, bf), lambda e_, c_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, d, bf), lambda e_, c_, f_: (e_, 0, f_)),
            pl.BlockSpec((1, bf, d), lambda e_, c_, f_: (e_, f_, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda e_, c_, f_: (e_, c_, 0)),
        out_shape=jax.ShapeDtypeStruct((e, cp, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, d), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
    return out[:, :c, :]

"""Pallas TPU kernel: flash attention (causal / sliding-window).

The §Perf roofline log (EXPERIMENTS.md §C) shows the dominant memory term
of long-sequence prefill is the fp32 logits chain — S²·H bytes of HBM
traffic at the XLA level.  This kernel keeps the (bq, bk) logits tile and
the online-softmax stats in VMEM and only ever writes the (bq, hd) output
accumulator, which removes that term on real TPU.

  grid = (B·H, S/bq, S/bk)   — k innermost, accumulating in VMEM scratch
  q   : (BH, S, hd)  block (1, bq, hd)
  k/v : (BH, S, hd)  block (1, bk, hd)
  out : (BH, S, hd)  block (1, bq, hd), written on the last k step

VMEM working set (bq=256, bk=512, hd=128, bf16):
  q 64 KB + k/v 2×128 KB + logits tile 512 KB (f32) + acc 128 KB ≈ 1 MB.

Validated against kernels/ref.py (and models/attention.attend_chunked)
in interpret mode on CPU; TPU is the deployment target.  Fully-masked
(bq, bk) tiles above the causal diagonal are still visited — a block-
sparse grid skip is a known further optimization, not needed for
correctness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, n_k: int, bq: int, bk: int, scale: float,
            window: int | None, causal: bool):
    kstep = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(kstep == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                    # (bq, hd)
    k = k_ref[0]                                    # (bk, hd)
    v = v_ref[0]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bk)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kstep * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]                             # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new)                     # (bq, bk)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)                  # (bq, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kstep == n_k - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_q", "block_k", "causal", "window", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = 256, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q/k/v: (B, H, S, hd) -> (B, H, S, hd). S padded to block multiples
    (zero-padded keys are masked by the causal/window mask; padded queries
    are sliced off)."""
    b, h, s, hd = q.shape
    bq, bk = min(block_q, s), min(block_k, s)
    sq = (s + bq - 1) // bq * bq
    sk = (s + bk - 1) // bk * bk
    sp = max(sq, sk)
    sp = (sp + max(bq, bk) - 1) // max(bq, bk) * max(bq, bk)

    def pad_to(x, target):
        return (x if x.shape[2] == target else
                jnp.pad(x, ((0, 0), (0, 0), (0, target - x.shape[2]),
                            (0, 0))))

    qp = pad_to(q, sp).reshape(b * h, sp, hd)
    kp = pad_to(k, sp).reshape(b * h, sp, hd)
    vp = pad_to(v, sp).reshape(b * h, sp, hd)
    n_q, n_k = sp // bq, sp // bk
    scale = hd ** -0.5

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, bq=bq, bk=bk, scale=scale,
                          window=window, causal=causal),
        grid=(b * h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out.reshape(b, h, sp, hd)[:, :, :s]

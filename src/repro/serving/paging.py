"""Host-side page management for the paged KV cache (docs/DESIGN.md §7).

The paper's central systems finding is that memory is the binding
constraint and memory-management churn the dominant overhead (§4.2/§5.4:
pre-allocated buffers remove the allocator from the hot loop).  The paged
cache keeps that property — ONE donated pool ``(L, num_pages, page_size,
Hkv, hd)`` allocated at engine start, never resized — while replacing the
contiguous slot-per-request reservation (every request pinning
``max_cache`` slots whether it uses 20 or 200) with page-granular
accounting:

  * :class:`PageAllocator` — free list + per-page reference counts.  All
    bookkeeping is host-side integers; the device never sees an
    allocation, only block tables (per-row page-id vectors) handed to the
    jit like ``lengths``.  ``fork`` shares pages between owners
    (refcount++), and ``writable`` implements copy-on-write: a page about
    to be written that has other owners is re-homed to a fresh page and
    the caller is told to issue a device page copy.
  * :class:`PrefixCache` — a radix tree over **page-sized token chunks**
    of completed prompts.  Requests sharing a system prompt map their
    leading block-table entries to the same physical pages and skip
    prefill for the shared prefix entirely (the Apple Foundation-Models
    serving shape: thousands of requests over one system prompt).  A node
    may also hold a *partial tail* record — the last, not-page-aligned
    chunk of a cached prompt — which a new request with the same prompt
    shares via copy-on-write (the tail page's owner keeps appending decode
    tokens to it, so the sharer copies the page and overwrites the
    divergent suffix as it generates).  Eviction is LRU over leaves: a
    leaf's tree reference is dropped and the page returns to the free list
    once no in-flight request maps it.

Sharing is exact, not approximate: a cache chunk is keyed by its literal
token bytes, and causal attention makes the K/V of a prompt prefix a pure
function of that prefix (MoE included, when dispatch capacity is not
binding), so a reused page is bit-identical to a recomputed one.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np


class PageAllocator:
    """Free-list page allocator with reference counting.

    Pages are integers in ``[0, num_pages)``.  Every mapped page has
    refcount >= 1; ``free`` decrements and returns the page to the free
    list at zero.  ``alloc`` is all-or-nothing (returns None rather than a
    partial allocation), so admission control can gate on
    ``free_pages`` without unwinding.  Invariants (property-tested in
    tests/test_paged_cache.py): a page is never in the free list twice,
    never both free and referenced, and after every owner releases its
    references the pool is fully free again.
    """

    def __init__(self, num_pages: int):
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.num_pages = num_pages
        # pop() hands out ascending ids — deterministic, test-friendly
        self._free = list(range(num_pages - 1, -1, -1))
        self._ref = [0] * num_pages

    # -- queries ------------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def fully_free(self) -> bool:
        """True when every page is back on the free list — the drain
        invariant the resilience gates check after every preempt /
        cancel / fault schedule."""
        return len(self._free) == self.num_pages

    def refcount(self, page: int) -> int:
        return self._ref[page]

    def check_consistent(self) -> None:
        """Raise if the free list and refcounts disagree: a page on the
        free list twice, a free page with owners, or a mapped page
        without a reference.  The chaos harness and the resilience
        property tests call this after every engine step, so a failure
        path that corrupts the accounting fails loudly at the step that
        broke it, not at drain."""
        if len(set(self._free)) != len(self._free):
            raise AssertionError("page on the free list twice")
        free = set(self._free)
        for p in range(self.num_pages):
            if p in free and self._ref[p] != 0:
                raise AssertionError(
                    f"page {p} is free but has refcount {self._ref[p]}")
            if p not in free and self._ref[p] <= 0:
                raise AssertionError(
                    f"page {p} is mapped but has refcount {self._ref[p]}")

    # -- ops ----------------------------------------------------------------

    def alloc(self, n: int) -> list[int] | None:
        """Take ``n`` pages (refcount 1 each); None if fewer are free."""
        if n < 0:
            raise ValueError(f"cannot alloc {n} pages")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def fork(self, pages: Iterable[int]) -> None:
        """Add one reference per page (a new owner shares existing pages)."""
        for p in pages:
            if self._ref[p] <= 0:
                raise ValueError(f"fork of unreferenced page {p}")
            self._ref[p] += 1

    def free(self, pages: Iterable[int]) -> None:
        """Drop one reference per page; a page with no owners left returns
        to the free list.  Freeing an already-free page raises — the
        double-free class of bug the property test hunts."""
        for p in pages:
            if self._ref[p] <= 0:
                raise ValueError(f"double free of page {p}")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)

    def writable(self, page: int) -> tuple[int, bool]:
        """Copy-on-write: return a page the caller may write.

        If the caller is the sole owner the page itself is returned.
        Otherwise one reference is moved to a freshly allocated page and
        ``(new_page, True)`` is returned — the caller must issue a device
        copy ``page -> new_page`` before writing.  Returns ``(page,
        False)`` on sole ownership; raises if no page is free for the
        copy (callers gate admission on ``free_pages`` first)."""
        if self._ref[page] <= 0:
            raise ValueError(f"writable() on unreferenced page {page}")
        if self._ref[page] == 1:
            return page, False
        got = self.alloc(1)
        if got is None:
            raise RuntimeError("no free page for copy-on-write")
        self._ref[page] -= 1
        return got[0], True


@dataclasses.dataclass
class _Node:
    """One radix-tree node = one full page of prompt tokens.

    ``children`` maps the NEXT chunk's token bytes to its node.  A node
    may additionally hold a partial-tail record: the page holding the
    first ``tail_len`` tokens after this node's chunk (a prompt whose
    length is not page-aligned).  The tree owns one allocator reference
    per ``page`` / ``tail_page`` it records."""
    page: int = -1                      # -1: root (no page of its own)
    children: dict = dataclasses.field(default_factory=dict)
    tail_page: int = -1
    tail_tokens: np.ndarray | None = None
    last_used: int = 0


@dataclasses.dataclass(frozen=True)
class PrefixHit:
    """Result of a prefix-cache lookup.  ``pages`` are full shared pages
    (the caller holds one reference on each); ``tokens`` counts full-page
    tokens plus ``tail_len`` tokens readable from ``tail_page`` (also
    referenced when >= 0).  The tail page must be copy-on-write'd before
    the request writes past the shared region."""
    pages: tuple
    tokens: int
    tail_page: int = -1
    tail_len: int = 0


class PrefixCache:
    """Radix tree of page-aligned prompt prefixes over physical pages.

    ``lookup`` walks full-page chunks while they match (capped at
    ``len(prompt) - 1`` shared tokens — at least one prompt token is
    always recomputed so the request has a logit to sample its first
    token from), then tries the terminal partial-tail record.  ``insert``
    is first-writer-wins: existing nodes keep their pages, only newly
    created nodes take a tree reference.  ``evict`` drops LRU leaves (and
    tail records) until enough allocator pages are free or nothing
    evictable remains; a page still mapped by an in-flight request merely
    loses its tree reference and returns to the pool when that request
    completes."""

    def __init__(self, page_size: int, allocator: PageAllocator):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self.alloc = allocator
        self.root = _Node()
        self._tick = 0
        self.cached_pages = 0           # pages the tree holds references on
        self.evictions = 0              # pages evicted (tree refs dropped)

    def _key(self, tokens: np.ndarray) -> bytes:
        return np.ascontiguousarray(tokens, np.int32).tobytes()

    def lookup(self, prompt: np.ndarray) -> PrefixHit:
        """Longest shared prefix of ``prompt`` present in the tree.  The
        caller receives one allocator reference per returned page (full
        and tail) and must ``free`` them when the request completes."""
        prompt = np.asarray(prompt, np.int32)
        ps = self.page_size
        cap = len(prompt) - 1           # always recompute >= 1 prompt token
        self._tick += 1
        node, pages = self.root, []
        while (len(pages) + 1) * ps <= cap:
            chunk = self._key(prompt[len(pages) * ps:(len(pages) + 1) * ps])
            child = node.children.get(chunk)
            if child is None:
                break
            pages.append(child.page)
            node = child
            node.last_used = self._tick
        hit_tokens = len(pages) * ps
        tail_page, tail_len = -1, 0
        if node.tail_page >= 0 and node.tail_tokens is not None:
            tt = node.tail_tokens
            usable = min(len(tt), cap - hit_tokens)
            if usable >= 1 and np.array_equal(
                    tt[:usable], prompt[hit_tokens:hit_tokens + usable]):
                tail_page, tail_len = node.tail_page, int(usable)
                node.last_used = self._tick
        self.alloc.fork(pages)
        if tail_page >= 0:
            self.alloc.fork([tail_page])
        return PrefixHit(tuple(pages), hit_tokens + tail_len,
                         tail_page, tail_len)

    def insert(self, prompt: np.ndarray, pages: Iterable[int],
               tail_page: int = -1, tail_len: int = 0) -> int:
        """Record a prefilled prompt: ``pages`` hold its full page-aligned
        chunks, ``tail_page`` its first ``tail_len`` overflow tokens.  The
        tree takes one reference per page it newly records; existing
        nodes are left untouched (their identical-content pages win).
        Returns the number of pages newly referenced."""
        prompt = np.asarray(prompt, np.int32)
        pages = list(pages)
        ps = self.page_size
        if len(pages) * ps + max(tail_len, 0) > len(prompt):
            raise ValueError("insert covers more tokens than the prompt")
        self._tick += 1
        node, added = self.root, 0
        for i, page in enumerate(pages):
            chunk = self._key(prompt[i * ps:(i + 1) * ps])
            child = node.children.get(chunk)
            if child is None:
                child = _Node(page=int(page))
                node.children[chunk] = child
                self.alloc.fork([page])
                self.cached_pages += 1
                added += 1
            child.last_used = self._tick
            node = child
        if tail_len >= 1 and tail_page >= 0 and node.tail_page < 0:
            node.tail_page = int(tail_page)
            node.tail_tokens = np.array(
                prompt[len(pages) * ps:len(pages) * ps + tail_len], np.int32)
            self.alloc.fork([tail_page])
            self.cached_pages += 1
            added += 1
        return added

    def _drop_tail(self, node: _Node) -> None:
        self.alloc.free([node.tail_page])
        node.tail_page, node.tail_tokens = -1, None
        self.cached_pages -= 1
        self.evictions += 1

    def reclaimable_pages(self) -> int:
        """Tree-held pages that would reach the free list if evicted NOW
        (refcount 1 — no in-flight request maps them).  Admission uses
        this to avoid draining the tree when eviction cannot possibly
        free enough pages (the pages are pinned by running requests)."""
        count = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            if node.tail_page >= 0 and self.alloc.refcount(node.tail_page) == 1:
                count += 1
            for child in node.children.values():
                if self.alloc.refcount(child.page) == 1:
                    count += 1
                stack.append(child)
        return count

    def evict(self, need_free: int) -> int:
        """Drop LRU leaves / tail records until ``allocator.free_pages >=
        need_free`` or the tree is exhausted.  Returns pages whose tree
        reference was dropped (they reach the free list only once no
        request maps them)."""
        dropped = 0
        while self.alloc.free_pages < need_free:
            victims = []                # (last_used, parent, key|None, node)
            stack = [(None, None, self.root)]
            while stack:
                parent, key, node = stack.pop()
                if node.tail_page >= 0:
                    victims.append((node.last_used, node, None))
                for k, child in node.children.items():
                    if child.children or child.tail_page >= 0:
                        stack.append((node, k, child))
                    else:
                        victims.append((child.last_used, node, k))
            if not victims:
                break
            _, parent, key = min(victims, key=lambda v: v[0])
            if key is None:             # tail record on ``parent``
                self._drop_tail(parent)
            else:
                child = parent.children.pop(key)
                self.alloc.free([child.page])
                self.cached_pages -= 1
                self.evictions += 1
            dropped += 1
        return dropped

    def clear(self) -> int:
        """Drop every tree reference (engine shutdown / benchmark warmup
        resets).  NOT eviction pressure: the ``evictions`` counter is
        preserved so reported stats only ever count admission-driven
        evictions."""
        before = self.evictions
        dropped = self.evict(self.alloc.num_pages + 1)
        self.evictions = before
        return dropped

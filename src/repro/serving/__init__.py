from repro.serving.engine import ServingEngine, EngineConfig, Request

"""Priority admission and preemption policy for the serving engine.

Pure host-side policy (docs/DESIGN.md §10): nothing here touches the
device.  The engine owns the *mechanism* — evicting a row's pages into
the prefix tree and restoring it later is `ServingEngine._preempt_slot`
/ `_admit_paged` — while this module owns the *decisions*: who waits
(:class:`AdmissionQueue`), who yields (:func:`select_victim`), and how
many pages a request is entitled to now vs. over its lifetime
(:func:`pages_for` / :func:`lifetime_pages`).

Scheduling contract, gated by tests/test_resilience.py:

  * higher ``Request.priority`` admits first; ties admit FIFO by
    submission sequence, and a preempted request keeps its original
    sequence so it re-enters *ahead* of later same-priority arrivals;
  * a victim is only ever chosen from strictly-lower-priority running
    rows at admission time (``below=``), or unconditionally under
    decode-growth pressure where *somebody* must yield a page;
  * among eligible victims the least-recently-preempted yields first
    (``epoch`` ascending), so no ready request is preempted twice in a
    row while a peer of no-higher priority keeps running — the
    fairness property test pins exactly this;
  * ties beyond that evict the youngest arrival (``seq`` descending),
    which drains the oldest requests first and gives the
    eventually-completes property its progress measure.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Iterable, Optional


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` cache positions."""
    return max(0, -(-tokens // page_size))


def lifetime_pages(ctx_len: int, remaining_new: int, page_size: int) -> int:
    """Whole-lifetime page count: positions written = context plus every
    generated token except the last (whose KV is never stored)."""
    return pages_for(ctx_len + max(remaining_new, 1) - 1, page_size)


@dataclasses.dataclass(frozen=True)
class RunningRow:
    """A candidate victim: one occupied engine slot."""
    slot: int
    priority: int
    epoch: int      # engine preemption epoch at this request's last
                    # preemption (0 = never preempted)
    seq: int        # submission sequence number


def select_victim(rows: Iterable[RunningRow], *,
                  below: Optional[int] = None,
                  exclude: tuple = ()) -> Optional[int]:
    """The slot that should yield its pages, or None if nobody is
    eligible.

    ``below`` restricts victims to priority strictly less than it (the
    admission-time rule: a request may only displace lesser work);
    ``below=None`` is growth pressure, where any running row — including
    the grower itself — may be chosen.  Ordering: lowest priority, then
    least-recently-preempted, then youngest arrival.
    """
    cands = [r for r in rows
             if r.slot not in exclude
             and (below is None or r.priority < below)]
    if not cands:
        return None
    return min(cands, key=lambda r: (r.priority, r.epoch, -r.seq)).slot


class AdmissionQueue:
    """Priority-ordered admission queue with the engine's old deque API.

    Orders by ``(-priority, seq)``: higher priority first, FIFO within a
    priority.  A preempted request re-``append``-ed here keeps the
    ``seq`` it was assigned at submit, so it outranks every
    same-priority request that arrived after it — preemption costs a
    request its slot, never its place in line.

    Supports the operations the engine and its callers already use on
    ``collections.deque``: truthiness, ``len``, iteration (in admission
    order), ``queue[0]`` peek, ``append``, ``popleft`` — plus
    ``remove(uid)`` for cancellation and deadline expiry.
    """

    def __init__(self):
        self._keys: list[tuple[int, int]] = []   # (-priority, seq)
        self._reqs: list = []

    def __len__(self) -> int:
        return len(self._reqs)

    def __bool__(self) -> bool:
        return bool(self._reqs)

    def __iter__(self):
        return iter(list(self._reqs))

    def __getitem__(self, idx):
        return self._reqs[idx]

    def append(self, req) -> None:
        key = (-req.priority, req.seq)
        i = bisect.bisect_right(self._keys, key)
        self._keys.insert(i, key)
        self._reqs.insert(i, req)

    def popleft(self):
        if not self._reqs:
            raise IndexError("pop from an empty AdmissionQueue")
        self._keys.pop(0)
        return self._reqs.pop(0)

    def remove(self, uid: int):
        """Drop and return the queued request with ``uid`` (None if not
        queued)."""
        for i, req in enumerate(self._reqs):
            if req.uid == uid:
                self._keys.pop(i)
                return self._reqs.pop(i)
        return None

    def clear(self) -> None:
        self._keys.clear()
        self._reqs.clear()

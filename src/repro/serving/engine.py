"""Serving engine: continuous batching with batched prefill, async decode
and device-side routing capture.

The paper's system serves a single user; this engine generalizes to batched
requests while keeping the paper's structure visible, and makes the hot
loop production-shaped:

  * **Batched prefill** — every engine iteration admits *all* queued
    requests into free decode slots with ONE jit call: the full-batch
    prefill runs over a (max_batch, prefill_len) token matrix and the
    resulting caches are merged row-wise under an admit mask, so in-flight
    slots are untouched.  (``EngineConfig.batched_prefill=False`` restores
    the legacy one-jit-call-per-request scatter prefill as a reference /
    baseline mode.)
  * **Device-side routing capture** — the forward pass returns every MoE
    layer's actual top-k decision as an auxiliary output
    (``Model.prefill_routed`` / ``decode_step_routed``; see
    ``core/expert_parallel.moe_layer``), and ``LRUExpertTracker`` consumes
    those.  The decode hot loop performs **zero host-side router
    evaluations**; the paper's Table-1 statistic
    ``E[#exec experts/node/layer]`` is exact, not a layer-0 embedding
    proxy.
  * **Zero-copy hot loop** — every jit donates its cache operand
    (``EngineConfig.donate_buffers``), and the model updates the cache with
    ``dynamic_update_slice`` on a scan carry, so the donated buffer aliases
    in place: the steady-state decode step contains no full-cache-sized
    copy (the JAX analogue of the paper's C1 pre-allocated buffers;
    HLO-verified in tests/test_zero_copy.py).  Small decode batches
    additionally skip the fixed-capacity dispatch via the capacity-free
    fast path (``ModelConfig.gather_decode_max_tk``): a per-token
    expert-weight gather (core/moe.gather_moe) when T·K fits under
    E_local, or a one-hot dense compute when T is below the capacity
    floor — on those forms there is no round_capacity padding, no
    dispatch-plan argsort/scatter and no drops.  When T·K is under the
    threshold but neither form is cheaper (T·K > E_local and T at/above
    the capacity floor), the fixed-capacity dispatch still runs with its
    usual capacity semantics.
  * **Async stepping** — decode steps are dispatched without
    ``block_until_ready``; per-step tokens and routing stay on device in a
    pending buffer and the host syncs only at request-completion
    boundaries (or on ``flush()``), overlapping host scheduling with
    device compute.  Budget-based termination means doneness never depends
    on token *values*, so the host can run ahead freely.
    (``EngineConfig.async_steps=False`` syncs every step — reference
    mode.)

Other paper artifacts are unchanged: ``standby`` reproduces the keep-warm
summing touch (§4.2) and the tracker's LRU structure is the faithful L_R
host half.

Static-shape serving: requests are right-padded to the slot length; the
scheduler packs arrivals into fixed decode slots (continuous batching).

Batch-capacity semantics (``moe_strategy="dispatch"``): per-expert dispatch
capacity scales with the whole admitted batch, so requests batched together
share one capacity pool — garbage/inactive rows are dead-routed via a
``token_mask`` and consume none of it, but real rows can admit tokens a
batch-1 dispatch would have dropped.  Token-for-token equality between
batched and sequential prefill is therefore exact whenever capacity is not
binding (the engine's intended serving regime, and always for
``moe_strategy="dense"``); under capacity pressure the pooled dispatch is
the intended continuous-batching behaviour, not a bug.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dynamic_load import LRUExpertTracker
from repro.models.model import build_model

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 32
    # filled by the engine
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 8            # decode slots
    prefill_len: int = 128        # prompts padded/truncated to this
    max_cache: int = 256          # KV/state cache length
    track_experts: bool = True
    batched_prefill: bool = True  # False: legacy per-request prefill
    async_steps: bool = True      # False: block_until_ready every step
    # Donate the cache operand of every jit in the hot loop (the JAX
    # analogue of the paper's C1 pre-allocated buffers): the model updates
    # the cache with dynamic_update_slice on a scan *carry*
    # (transformer._scan_stack_with_cache), so the donated buffer aliases in
    # place and the steady-state decode step performs no full-cache-sized
    # copy (HLO-verified in tests/test_zero_copy.py).  False restores the
    # copy-per-step baseline for A/B measurement.  Values are unaffected
    # either way; only ``last_tok``/routing stay undonated because async
    # mode's pending harvest buffer still references them after dispatch.
    donate_buffers: bool = True


@dataclasses.dataclass(frozen=True)
class _Pending:
    """One dispatched-but-unharvested device step.

    ``rows`` binds batch rows to their requests *at dispatch time* (slots
    may be re-assigned before the harvest sync).  ``tok`` is the post-step
    (B,) last-token vector; ``routing`` the (L, T, K) device capture (None
    for dense archs / disabled tracking).  ``routing_batch`` is the batch
    size of the dispatched call (1 for the legacy batch-1 prefill, whose
    capture row is always 0)."""
    kind: str                     # "prefill" | "decode"
    rows: tuple                   # ((row_in_routing, slot, Request), ...)
    tok: Any
    routing: Any
    routing_batch: int


class ServingEngine:
    """Continuous-batching engine over the pure-functional Model API."""

    def __init__(self, cfg_model, engine_cfg: EngineConfig | None = None,
                 params=None, rng=None, mesh=None):
        self.cfg = cfg_model
        self.ecfg = engine_cfg or EngineConfig()
        self.mesh = mesh
        self.model = build_model(cfg_model)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.params = params if params is not None else self.model.init(rng)
        if mesh is not None:
            from repro.launch import sharding as sharding_lib
            spec = sharding_lib.params_pspec(cfg_model, mesh, self.params,
                                             mode="serve")
            self.params = jax.device_put(
                self.params, sharding_lib.named(mesh, spec))
        self.tracker = (LRUExpertTracker(cfg_model.num_layers,
                                         cfg_model.num_experts)
                        if cfg_model.is_moe and self.ecfg.track_experts
                        else None)
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[Request | None] = [None] * self.ecfg.max_batch
        self._all: dict[int, Request] = {}
        self._uid = 0
        b, c = self.ecfg.max_batch, self.ecfg.max_cache
        self.cache = self.model.init_cache(b, c)
        self.lengths = np.zeros((b,), np.int32)
        self.budgets = np.zeros((b,), np.int32)
        self.last_tok = jnp.zeros((b,), jnp.int32)
        self._pending: list[_Pending] = []
        # cache is argument 1 of every jit body; self.cache is rebound to the
        # output before the next dispatch, so donating it is always safe.
        donate = (1,) if self.ecfg.donate_buffers else ()
        self._jit_prefill_batch = jax.jit(self._prefill_batch,
                                          donate_argnums=donate)
        self._jit_prefill_one = jax.jit(self._prefill_one,
                                        donate_argnums=donate)
        self._jit_decode = jax.jit(self._decode, donate_argnums=donate)
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "decode_tokens": 0, "prefill_s": 0.0, "decode_s": 0.0,
                      "harvest_s": 0.0, "harvests": 0}

    # -- jit bodies ---------------------------------------------------------

    def _greedy_next(self, logits: Array) -> Array:
        return jnp.argmax(logits[:, :self.cfg.vocab_size],
                          axis=-1).astype(jnp.int32)

    def _prefill_batch(self, params, cache, tokens, admit_mask, last_tok):
        """Admit up to max_batch requests in ONE call.

        tokens: (B, prefill_len) — zeros on non-admitted rows;
        admit_mask: (B,) bool.  The full-batch prefill recomputes every row
        (static shapes, one XLA program); the cache is then merged row-wise
        so in-flight slots keep their state.  Returns (last_tok', cache',
        routing) with last_tok' holding each admitted row's first sampled
        token."""
        tmask = jnp.broadcast_to(admit_mask[:, None], tokens.shape)
        logits, new_cache, routing = self.model.prefill_routed(
            params, {"tokens": tokens, "token_mask": tmask}, cache, self.mesh)
        nxt = self._greedy_next(logits[:, -1])

        def merge(old, new):
            if old.ndim < 2:      # scalar bookkeeping leaves, if any
                return new
            m = admit_mask.reshape((1, old.shape[1]) + (1,) * (old.ndim - 2))
            return jnp.where(m, new, old)

        cache = jax.tree.map(merge, cache, new_cache)
        last_tok = jnp.where(admit_mask, nxt, last_tok)
        return last_tok, cache, routing

    def _prefill_one(self, params, cache, tokens, slot, last_tok):
        """Legacy reference path: batch-1 prefill scattered into ``slot``.

        The batch-1 working cache is *sliced* out of the full cache rather
        than zero-materialized: the old ``jnp.zeros`` + scatter pattern
        allocated a fresh per-slot cache copy every admit, while the slice
        reads one row and (under donation) scatters it back in place.
        Prefill overwrites the whole prompt region and decode masks by
        ``lengths``, so any stale tail beyond the prompt is never attended —
        the same invariant the batched path relies on when it recomputes
        in-flight rows under the admit mask."""
        one_cache = jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1)
            if a.ndim >= 2 else a, cache)
        logits, one_cache, routing = self.model.prefill_routed(
            params, {"tokens": tokens}, one_cache, self.mesh)
        cache = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_index_in_dim(
                full, one[:, 0], slot, axis=1), cache, one_cache)
        nxt = self._greedy_next(logits[:, -1])  # (1,)
        last_tok = jax.lax.dynamic_update_index_in_dim(
            last_tok, nxt[0], slot, axis=0)
        return last_tok, cache, routing

    def _decode(self, params, cache, last_tok, lengths, active_mask):
        logits, cache, routing = self.model.decode_step_routed(
            params, cache, {"tokens": last_tok[:, None], "lengths": lengths,
                            "token_mask": active_mask[:, None]},
            self.mesh)
        nxt = self._greedy_next(logits[:, -1])
        last_tok = jnp.where(active_mask, nxt, last_tok)
        return last_tok, cache, routing

    # -- public API ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        self._uid += 1
        req = Request(self._uid, np.asarray(prompt, np.int32), max_new_tokens)
        self.queue.append(req)
        self._all[req.uid] = req
        return self._uid

    def _pad_prompt(self, req: Request) -> np.ndarray:
        p = req.prompt[-self.ecfg.prefill_len:]
        pad = np.zeros((self.ecfg.prefill_len,), np.int32)
        pad[:len(p)] = p
        return pad

    def _admit(self) -> None:
        free = [i for i, r in enumerate(self.slots) if r is None]
        if not free or not self.queue:
            return
        if self.ecfg.batched_prefill:
            self._admit_batched(free)
        else:
            self._admit_sequential(free)

    def _post_admit(self, rows, routing, routing_batch: int) -> None:
        for _, slot, req in rows:
            self.slots[slot] = req
            self.lengths[slot] = self.ecfg.prefill_len
            self.budgets[slot] = req.max_new_tokens - 1
            self.stats["prefill_tokens"] += self.ecfg.prefill_len
        self._pending.append(_Pending("prefill", tuple(rows), self.last_tok,
                                      routing, routing_batch))
        if not self.ecfg.async_steps:
            self._harvest()

    def _admit_batched(self, free: list[int]) -> None:
        rows = []
        tokens = np.zeros((self.ecfg.max_batch, self.ecfg.prefill_len),
                          np.int32)
        admit = np.zeros((self.ecfg.max_batch,), bool)
        for slot in free:
            if not self.queue:
                break
            req = self.queue.popleft()
            tokens[slot] = self._pad_prompt(req)
            admit[slot] = True
            rows.append((slot, slot, req))
        t0 = time.perf_counter()
        # tokens/admit are freshly built per call and never mutated after
        # dispatch (see the transfer note in step())
        self.last_tok, self.cache, routing = self._jit_prefill_batch(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(admit),
            self.last_tok)
        if not self.ecfg.async_steps:
            self.last_tok.block_until_ready()
        self.stats["prefill_s"] += time.perf_counter() - t0
        self._post_admit(rows, routing, self.ecfg.max_batch)

    def _admit_sequential(self, free: list[int]) -> None:
        for slot in free:
            if not self.queue:
                break
            req = self.queue.popleft()
            tokens = self._pad_prompt(req)[None]
            t0 = time.perf_counter()
            self.last_tok, self.cache, routing = self._jit_prefill_one(
                self.params, self.cache, jnp.asarray(tokens), slot,
                self.last_tok)
            if not self.ecfg.async_steps:
                self.last_tok.block_until_ready()
            self.stats["prefill_s"] += time.perf_counter() - t0
            self._post_admit([(0, slot, req)], routing, 1)

    def step(self) -> int:
        """One engine iteration: admit + one decode step. Returns #active.

        In async mode the device step is only *dispatched* here; tokens are
        appended to requests at the next harvest boundary (a request
        finishing, ``flush()``, or sync mode)."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        mask = np.zeros((self.ecfg.max_batch,), bool)
        mask[active] = True
        t0 = time.perf_counter()
        # NB: self.lengths is handed to the device as a host-side SNAPSHOT
        # (.copy()) that nothing mutates afterwards.  The host→device
        # transfer is itself deferred on jaxlib 0.4.x CPU — even
        # jnp.array's copy can read the source buffer *after* the
        # `self.lengths[i] += 1` below, which under CPU load produced
        # stale-length decodes (KV written over the previous slot,
        # repeated tokens).  mask/tokens buffers are freshly built per
        # call and never mutated after dispatch, so they are safe as-is.
        self.last_tok, self.cache, routing = self._jit_decode(
            self.params, self.cache, self.last_tok,
            jnp.asarray(self.lengths.copy()), jnp.asarray(mask))
        if not self.ecfg.async_steps:
            self.last_tok.block_until_ready()
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_steps"] += 1
        rows = tuple((i, i, self.slots[i]) for i in active)
        self._pending.append(_Pending("decode", rows, self.last_tok, routing,
                                      self.ecfg.max_batch))
        finishing = False
        for i in active:
            self.lengths[i] = min(self.lengths[i] + 1, self.ecfg.max_cache)
            self.stats["decode_tokens"] += 1
            self.budgets[i] -= 1
            if self.budgets[i] <= 0:
                # budget-based completion is host-known at dispatch time:
                # free the slot now, collect the tokens at the harvest below
                self.slots[i] = None
                finishing = True
        if finishing or not self.ecfg.async_steps:
            self._harvest()
        return len(active)

    # -- harvest: the only device sync in the loop --------------------------

    def _harvest(self) -> None:
        """Fetch all pending step outputs and apply them to requests/tracker
        in dispatch order.  Each record is fetched with its own timed
        ``device_get`` — computations complete in dispatch order, so the
        per-record wait IS that step's remaining device time, giving an
        honest prefill/decode split of the async pipeline's wall clock."""
        if not self._pending:
            return
        recs, self._pending = self._pending, []
        self.stats["harvests"] += 1
        for rec in recs:
            t0 = time.perf_counter()
            tok, routing = jax.device_get((rec.tok, rec.routing))
            dt = time.perf_counter() - t0
            self.stats["harvest_s"] += dt
            self.stats["prefill_s" if rec.kind == "prefill" else
                       "decode_s"] += dt
            for _, slot, req in rec.rows:
                req.generated.append(int(tok[slot]))
                if len(req.generated) >= req.max_new_tokens:
                    req.done = True
            self._observe_routing(rec, routing)

    def _observe_routing(self, rec: _Pending, routing) -> None:
        """Feed the tracker from the device capture (host does NO routing)."""
        if self.tracker is None or routing is None:
            return
        # prefill: (L, B*S, K) -> (L, B, S*K); decode: (L, B, K) unchanged
        per_row = routing.reshape(routing.shape[0], rec.routing_batch, -1)
        row_ids = [row for row, _, _ in rec.rows]
        for layer in range(self.cfg.num_layers):
            self.tracker.observe(layer, per_row[layer, row_ids])
        self.tracker.tick()

    def flush(self) -> None:
        """Sync: harvest every dispatched-but-unapplied step."""
        self._harvest()

    def run_until_done(self, max_steps: int = 10_000) -> list[Request]:
        done: list[Request] = []
        seen: set[int] = set()
        pending = lambda: self.queue or any(s is not None for s in self.slots)
        steps = 0
        while pending() and steps < max_steps:
            self.step()
            steps += 1
            for r in self._all.values():
                if r.done and r.uid not in seen:
                    seen.add(r.uid)
                    done.append(r)
        self.flush()
        for r in self._all.values():
            if r.done and r.uid not in seen:
                seen.add(r.uid)
                done.append(r)
        return done

    # -- paper policy artifacts ---------------------------------------------

    def standby(self) -> Array:
        """The paper's between-request keep-warm: a summing touch over every
        expert weight (§4.2 'standby calculation')."""
        if not self.cfg.is_moe:
            return jnp.zeros(())
        ex = self.params["blocks"]["experts"]
        return sum(jnp.sum(w.astype(jnp.float32)) for w in jax.tree.leaves(ex))

    def expected_experts_per_node(self, n_nodes: int) -> float:
        """Measured Table-1 statistic from the tracker (exact: computed from
        the device-captured routing decisions of every served step)."""
        if self.tracker is None:
            return float("nan")
        self.flush()
        return self.tracker.mean_executed_per_node(n_nodes)

    def throughput(self) -> dict:
        """Per-phase tok/s.  ``prefill_s``/``decode_s`` hold dispatch time
        plus each phase's harvest wait (see _harvest), so the split is
        meaningful in async mode too; ``total`` is the combined rate."""
        s = self.stats
        return {
            "prefill_tok_per_s": s["prefill_tokens"] / max(s["prefill_s"], 1e-9),
            "decode_tok_per_s": s["decode_tokens"] / max(s["decode_s"], 1e-9),
            "total_tok_per_s": (s["prefill_tokens"] + s["decode_tokens"])
                               / max(s["prefill_s"] + s["decode_s"], 1e-9),
        }

"""Serving engine: batched prefill + decode with request scheduling and the
paper's host-side L_R policy artifacts.

The paper's system serves a single user; this engine generalizes to batched
requests while keeping the paper's structure visible:

  * prefill and decode are separate jit'd entry points (the paper's "prompt
    evaluation" vs "token generation" phases, reported separately in §5.2);
  * the ``LRUExpertTracker`` observes per-layer routing decisions of every
    step and exposes E[#exec experts/node/layer] — the measured statistic
    that parameterizes the perf model (Table 1);
  * a ``standby`` hook reproduces the paper's keep-warm trick (a summing
    touch over every expert's weights between requests).  On TPU it is a
    no-op for correctness but is kept (and tested) as the faithful policy.

Static-shape serving: requests are right-padded to the slot length; the
scheduler packs arrivals into fixed decode slots (continuous batching).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dynamic_load import LRUExpertTracker
from repro.core import router as router_lib
from repro.models.model import build_model

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 32
    # filled by the engine
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 8            # decode slots
    prefill_len: int = 128        # prompts padded/truncated to this
    max_cache: int = 256          # KV/state cache length
    greedy: bool = True
    temperature: float = 1.0
    track_experts: bool = True


class ServingEngine:
    """Continuous-batching engine over the pure-functional Model API."""

    def __init__(self, cfg_model, engine_cfg: EngineConfig | None = None,
                 params=None, rng=None, mesh=None):
        self.cfg = cfg_model
        self.ecfg = engine_cfg or EngineConfig()
        self.mesh = mesh
        self.model = build_model(cfg_model)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.params = params if params is not None else self.model.init(rng)
        if mesh is not None:
            from repro.launch import sharding as sharding_lib
            spec = sharding_lib.params_pspec(cfg_model, mesh, self.params,
                                             mode="serve")
            self.params = jax.device_put(
                self.params, sharding_lib.named(mesh, spec))
        self.tracker = (LRUExpertTracker(cfg_model.num_layers,
                                         cfg_model.num_experts)
                        if cfg_model.is_moe and self.ecfg.track_experts
                        else None)
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[Request | None] = [None] * self.ecfg.max_batch
        self._all: dict[int, Request] = {}
        self._uid = 0
        b, c = self.ecfg.max_batch, self.ecfg.max_cache
        self.cache = self.model.init_cache(b, c)
        self.lengths = np.zeros((b,), np.int32)
        self.budgets = np.zeros((b,), np.int32)
        self.last_tok = np.zeros((b,), np.int32)
        self._jit_prefill_one = jax.jit(self._prefill_one)
        self._jit_decode = jax.jit(self._decode)
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "decode_tokens": 0, "prefill_s": 0.0, "decode_s": 0.0}

    # -- jit bodies ---------------------------------------------------------

    def _prefill_one(self, params, cache, tokens, slot):
        """Prefill one request into batch row ``slot`` of the engine cache.

        tokens: (1, prefill_len). Runs a batch-1 prefill then scatters the
        resulting per-layer cache rows into the engine-wide cache."""
        one_cache = jax.tree.map(
            lambda a: jnp.zeros((a.shape[0], 1) + a.shape[2:], a.dtype)
            if a.ndim >= 2 else a, cache)
        logits, one_cache = self.model.prefill(params, {"tokens": tokens},
                                               one_cache, self.mesh)
        cache = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_index_in_dim(
                full, one[:, 0], slot, axis=1), cache, one_cache)
        return logits[:, -1], cache

    def _decode(self, params, cache, tokens, lengths):
        logits, cache = self.model.decode_step(
            params, cache, {"tokens": tokens, "lengths": lengths}, self.mesh)
        return logits[:, -1], cache

    # -- public API ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        self._uid += 1
        req = Request(self._uid, np.asarray(prompt, np.int32), max_new_tokens)
        self.queue.append(req)
        self._all[req.uid] = req
        return self._uid

    def _admit(self) -> None:
        for slot in range(self.ecfg.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            p = req.prompt[-self.ecfg.prefill_len:]
            pad = np.zeros((self.ecfg.prefill_len,), np.int32)
            pad[:len(p)] = p
            t0 = time.perf_counter()
            logits, self.cache = self._jit_prefill_one(
                self.params, self.cache, pad[None], slot)
            logits.block_until_ready()
            self.stats["prefill_s"] += time.perf_counter() - t0
            self.stats["prefill_tokens"] += self.ecfg.prefill_len
            tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
            req.generated.append(tok)
            self.slots[slot] = req
            self.lengths[slot] = self.ecfg.prefill_len
            self.budgets[slot] = req.max_new_tokens - 1
            self.last_tok[slot] = tok
            self._observe_routing(pad[None])

    def _observe_routing(self, tokens: np.ndarray) -> None:
        """Host-side L_R bookkeeping: per-layer expert hits for this batch."""
        if self.tracker is None:
            return
        # cheap host-side router replay on the embedding (layer-0 proxy per
        # layer is exact for the router inputs we track: we use each layer's
        # router over the running hidden state only in tests; here we track
        # layer-0 embeddings as the paper's statistic is layer-averaged).
        emb = np.asarray(jax.device_get(
            jnp.take(self.params["embed"],
                     jnp.clip(tokens, 0, self.cfg.vocab_size - 1), axis=0)))
        x = jnp.asarray(emb.reshape(-1, self.cfg.d_model))
        blocks = self.params["blocks"]
        for layer in range(self.cfg.num_layers):
            rw = jax.tree.map(lambda a: a[layer], blocks["router"])
            out = router_lib.route(rw, x, self.cfg.experts_per_token,
                                   n_valid_experts=self.cfg.num_experts)
            self.tracker.observe(layer, np.asarray(out.top_idx).reshape(-1))
        self.tracker.tick()

    def step(self) -> int:
        """One engine iteration: admit + one decode step. Returns #active."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        toks = jnp.asarray(self.last_tok[:, None])
        lens = jnp.asarray(self.lengths)
        t0 = time.perf_counter()
        logits, self.cache = self._jit_decode(self.params, self.cache,
                                              toks, lens)
        logits.block_until_ready()
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_steps"] += 1
        nxt = np.asarray(jnp.argmax(logits[:, :self.cfg.vocab_size], axis=-1))
        self._observe_routing(self.last_tok[:, None])
        for i in active:
            req = self.slots[i]
            self.lengths[i] = min(self.lengths[i] + 1, self.ecfg.max_cache)
            self.stats["decode_tokens"] += 1
            req.generated.append(int(nxt[i]))
            self.last_tok[i] = int(nxt[i])
            self.budgets[i] -= 1
            if self.budgets[i] <= 0:
                req.done = True
                self.slots[i] = None
        return len(active)

    def run_until_done(self, max_steps: int = 10_000) -> list[Request]:
        done: list[Request] = []
        seen: set[int] = set()
        pending = lambda: self.queue or any(s is not None for s in self.slots)
        steps = 0
        while pending() and steps < max_steps:
            self.step()
            steps += 1
            for r in self._all.values():
                if r.done and r.uid not in seen:
                    seen.add(r.uid)
                    done.append(r)
        return done

    # -- paper policy artifacts ---------------------------------------------

    def standby(self) -> Array:
        """The paper's between-request keep-warm: a summing touch over every
        expert weight (§4.2 'standby calculation')."""
        if not self.cfg.is_moe:
            return jnp.zeros(())
        ex = self.params["blocks"]["experts"]
        return sum(jnp.sum(w.astype(jnp.float32)) for w in jax.tree.leaves(ex))

    def expected_experts_per_node(self, n_nodes: int) -> float:
        """Measured Table-1 statistic from the tracker."""
        if self.tracker is None:
            return float("nan")
        return self.tracker.mean_executed_per_node(n_nodes)

    def throughput(self) -> dict:
        s = self.stats
        return {
            "prefill_tok_per_s": s["prefill_tokens"] / max(s["prefill_s"], 1e-9),
            "decode_tok_per_s": s["decode_tokens"] / max(s["decode_s"], 1e-9),
        }

"""Serving engine: continuous batching with batched prefill, async decode
and device-side routing capture.

The paper's system serves a single user; this engine generalizes to batched
requests while keeping the paper's structure visible, and makes the hot
loop production-shaped:

  * **Batched prefill** — every engine iteration admits *all* queued
    requests into free decode slots with ONE jit call: the full-batch
    prefill runs over a (max_batch, prefill_len) token matrix and the
    resulting caches are merged row-wise under an admit mask, so in-flight
    slots are untouched.  (``EngineConfig.batched_prefill=False`` restores
    the legacy one-jit-call-per-request scatter prefill as a reference /
    baseline mode.)
  * **Device-side routing capture** — the forward pass returns every MoE
    layer's actual top-k decision as an auxiliary output
    (``Model.prefill_routed`` / ``decode_step_routed``; see
    ``core/expert_parallel.moe_layer``), and ``LRUExpertTracker`` consumes
    those.  The decode hot loop performs **zero host-side router
    evaluations**; the paper's Table-1 statistic
    ``E[#exec experts/node/layer]`` is exact, not a layer-0 embedding
    proxy.
  * **Zero-copy hot loop** — every jit donates its cache operand
    (``EngineConfig.donate_buffers``), and the model updates the cache with
    ``dynamic_update_slice`` on a scan carry, so the donated buffer aliases
    in place: the steady-state decode step contains no full-cache-sized
    copy (the JAX analogue of the paper's C1 pre-allocated buffers;
    HLO-verified in tests/test_zero_copy.py).  Small decode batches
    additionally skip the fixed-capacity dispatch via the capacity-free
    fast path (``ModelConfig.gather_decode_max_tk``): a per-token
    expert-weight gather (core/moe.gather_moe) when T·K fits under
    E_local, or a one-hot dense compute when T is below the capacity
    floor — on those forms there is no round_capacity padding, no
    dispatch-plan argsort/scatter and no drops.  When T·K is under the
    threshold but neither form is cheaper (T·K > E_local and T at/above
    the capacity floor), the fixed-capacity dispatch still runs with its
    usual capacity semantics.
  * **Async stepping** — decode steps are dispatched without
    ``block_until_ready``; per-step tokens and routing stay on device in a
    pending buffer and the host syncs only at request-completion
    boundaries (or on ``flush()``), overlapping host scheduling with
    device compute.  Budget-based termination means doneness never depends
    on token *values*, so the host can run ahead freely.
    (``EngineConfig.async_steps=False`` syncs every step — reference
    mode.)

Other paper artifacts are unchanged: ``standby`` reproduces the keep-warm
summing touch (§4.2) and the tracker's LRU structure is the faithful L_R
host half.

  * **Unified token-budget step** (``EngineConfig.unified_step``, default
    on) — prefill and decode are ONE jit program
    (``Model.forward_routed``): every iteration packs the active decode
    rows *and* up to ``token_budget`` pending prefill-chunk tokens into a
    single (max_batch, chunk_len) block at per-row cache offsets.  Long
    prompts stream through the cache ``chunk_len`` tokens per iteration
    (no padding to ``prefill_len``, no truncation — prompts up to
    ``max_cache``), and admission never stalls in-flight decode rows: a
    decode slot advances one token every iteration regardless of how much
    prefill work is queued.  ``unified_step=False`` restores the
    two-program reference engine (padded whole-prompt prefill + one-token
    decode) for A/B token-equality and perf comparison.
  * **Per-request sampling** — ``Request.temperature`` / ``top_k`` are
    applied inside the jit step (greedy argmax when temperature=0, the
    default; otherwise per-row top-k Gumbel sampling with an RNG folded on
    (engine step, slot)).  Token-equality gates always run at
    temperature=0.
  * **Paged KV cache + prefix reuse** (``EngineConfig.paged``;
    docs/DESIGN.md §7) — the contiguous (max_batch, max_cache)
    slot-per-request cache is replaced by ONE donated page pool
    (num_pages, page_size, Hkv, hd) per layer plus per-row block tables:
    a request consumes only the pages its context needs, admission is
    gated on free pages (host-side free list + refcounts,
    serving/paging.py), and a radix prefix cache maps requests sharing a
    system prompt onto the same physical pages — their shared prefix is
    never re-prefilled (partial tail pages shared via copy-on-write).
    Token-for-token equal to the contiguous unified path under
    non-binding capacity; the donated paged program still contains no
    pool-sized copy (tests/test_zero_copy.py).

  * **Quantized weight store** (``ModelConfig.weight_quant``;
    docs/DESIGN.md §8) — params load as blockwise int8 / packed-int4
    ``QuantTensor`` leaves (payload + per-block fp32 scales as sibling
    arrays; router and embedding stay fp) via a one-time
    quantize-on-load pass, and every matmul site dequantizes through the
    ``core/quant.qdot`` policy point — the hot loop, donation, sharding
    and routing capture are representation-agnostic.
    ``memory_stats()`` reports the resulting device weight + KV pool
    bytes (int8 shrinks weights >= 3.5x, int4 >= 6x, at fp router).
    Correctness gate: token-identical to the fake-quant fp reference
    (tests/test_quant.py, CI perf-smoke).

Static-shape serving: the reference path right-pads requests to the slot
length; the unified path streams chunks through a fixed (max_batch,
chunk_len) block.  The scheduler packs arrivals into fixed decode slots
(continuous batching).

Batch-capacity semantics (``moe_strategy="dispatch"``): per-expert dispatch
capacity scales with the whole admitted batch, so requests batched together
share one capacity pool — garbage/inactive rows are dead-routed via a
``token_mask`` and consume none of it, but real rows can admit tokens a
batch-1 dispatch would have dropped.  Token-for-token equality between
batched and sequential prefill is therefore exact whenever capacity is not
binding (the engine's intended serving regime, and always for
``moe_strategy="dense"``); under capacity pressure the pooled dispatch is
the intended continuous-batching behaviour, not a bug.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.dynamic_load import LRUExpertTracker
from repro.models.model import build_model
from repro.serving import scheduler as sched
from repro.serving.faults import InjectedFault
from repro.serving.paging import PageAllocator, PrefixCache

Array = jax.Array

# Terminal request states: a request in one of these never transitions
# again (cancel() on it is a no-op returning False) and its pages are
# already released.
TERMINAL_STATES = ("done", "cancelled", "expired", "failed")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 32
    # per-request sampling params (greedy when temperature == 0)
    temperature: float = 0.0
    top_k: int = 0                # 0 = no top-k cut (full vocab)
    # scheduling class (docs/DESIGN.md §10): higher admits first; ties
    # admit FIFO.  deadline_s is an absolute time.perf_counter() stamp
    # past which the request is expired instead of served.
    priority: int = 0
    deadline_s: float | None = None
    # filled by the engine
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    submit_s: float = 0.0         # wall clock at submit()
    first_token_s: float | None = None  # wall clock when token 1 harvested
    # scheduler state (docs/DESIGN.md §10): queued -> running <->
    # preempted -> done | cancelled | expired | failed
    status: str = "queued"
    seq: int = 0                  # submission order; kept across preemption
    preemptions: int = 0          # times this request lost its slot
    last_preempt_epoch: int = 0   # engine epoch of the last preemption
    # virtual prompt at re-admission: original prompt + every token
    # generated before the preemption (its cache pages live in the
    # prefix tree, so restore re-prefills at most one partial chunk)
    resume_tokens: np.ndarray | None = None
    nan_retries: int = 0          # consecutive quarantined steps


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 8            # decode slots
    prefill_len: int = 128        # reference mode: prompts padded to this
    max_cache: int = 256          # KV/state cache length
    track_experts: bool = True
    batched_prefill: bool = True  # False: legacy per-request prefill
    async_steps: bool = True      # False: block_until_ready every step
    # Unified token-budget forward pass (the production path): prefill and
    # decode share ONE jit program (Model.forward_routed); each iteration
    # packs active decode rows plus pending prefill chunks into a single
    # (max_batch, chunk_len) block at per-row cache offsets.  Prompts of
    # any length up to max_cache stream through the cache chunk_len tokens
    # per iteration — no prefill_len padding/truncation, and admission
    # never stalls decode.  False restores the two-program reference
    # engine (whole-prompt padded prefill + one-token decode) for A/B
    # token-equality and perf comparison.  Families without a unified
    # forward (ssm/hybrid/vlm/audio) silently fall back to the reference
    # path.
    unified_step: bool = True
    chunk_len: int = 32           # unified block width / prefill chunk size
    # Per-iteration cap on scheduled prefill tokens (0 = unlimited).
    # Decode rows are exempt: they always advance.  The budget throttles
    # how much prefill work shares an iteration with decode, bounding the
    # per-iteration latency a decode token can see.
    token_budget: int = 0
    sample_seed: int = 0          # RNG seed for stochastic decode
    # Paged KV cache (docs/DESIGN.md §7): replace the contiguous
    # (max_batch, max_cache) slot-per-request cache with ONE donated page
    # pool (num_pages, page_size, Hkv, hd) per layer plus per-row block
    # tables.  A request consumes ceil((prompt + max_new_tokens - 1) /
    # page_size) pages instead of reserving max_cache slots, admission is
    # gated on FREE PAGES (a host-side free list + refcounts,
    # serving/paging.PageAllocator), and a radix prefix cache maps
    # requests sharing a system prompt onto the same physical pages so
    # the shared prefix's prefill is skipped entirely (partial tail pages
    # shared via copy-on-write).  Requires the unified scheduler (paged
    # mode streams chunks; ring-cache archs keep the reference path).
    # Token-for-token equal to the contiguous unified path under
    # non-binding capacity (tests/test_paged_cache.py + CI perf-smoke).
    paged: bool = False
    page_size: int = 16           # tokens per page
    # Pool size in pages; 0 = auto (max_batch * ceil(max_cache /
    # page_size) — the same token capacity as the contiguous layout, so
    # paged-vs-contiguous A/Bs run at equal pool bytes).
    num_pages: int = 0
    # Overcommit the page pool (docs/DESIGN.md §10; requires paged):
    # admission allocates only the pages the CONTEXT needs (lazy decode
    # growth takes one page at a time as rows advance) instead of the
    # whole ceil((prompt + max_new - 1) / page_size) lifetime, so more
    # requests run concurrently at equal pool bytes.  When growth or a
    # higher-priority admission finds the pool short, a low-priority
    # row is PREEMPTED: its pages move into the prefix tree, the
    # request is requeued, and restore is a block-table remap plus at
    # most one partial-tail re-prefill chunk — greedy token streams are
    # identical to the unpreempted run (tests/test_resilience.py).
    # False keeps PR4's conservative whole-lifetime admission: an
    # admitted request can never hit pool OOM mid-generation.
    overcommit: bool = False
    # Paged-attention Pallas kernel (kernels/paged_attn.py; requires
    # paged): attention walks the block table page by page in VMEM —
    # online softmax, grouped GQA, in-kernel int8 dequant — instead of
    # gathering each row's pages into a (B, NB*page_size, Hkv, hd)
    # virtual cache.  Attention reads then scale with row lengths, not
    # pool size (docs/DESIGN.md §11); the gather path stays as the
    # reference (token-identical under greedy, gated in CI perf-smoke
    # and the chaos matrix).  Single-host only: mesh-sharded serving
    # keeps the gather path, whose XLA ops shard under GSPMD.
    paged_kernel: bool = False
    # NaN/Inf logit quarantine (serving/faults.py): when on, every
    # unified step reads back the jit's per-row finiteness flag
    # (_quarantine_check — a deliberate per-step device sync, the same
    # opt-in trade as async_steps=False) and withholds the host-state
    # advance of any non-finite row so it retries from its last durable
    # cache state.  None = auto: enabled iff a fault plan is installed.
    nan_guard: bool | None = None
    # consecutive non-finite steps before a quarantined row is failed
    nan_retry_limit: int = 3
    # Donate the cache operand of every jit in the hot loop (the JAX
    # analogue of the paper's C1 pre-allocated buffers): the model updates
    # the cache with dynamic_update_slice on a scan *carry*
    # (transformer._scan_stack_with_cache), so the donated buffer aliases in
    # place and the steady-state decode step performs no full-cache-sized
    # copy (HLO-verified in tests/test_zero_copy.py).  False restores the
    # copy-per-step baseline for A/B measurement.  Values are unaffected
    # either way; only ``last_tok``/routing stay undonated because async
    # mode's pending harvest buffer still references them after dispatch.
    donate_buffers: bool = True


@dataclasses.dataclass(frozen=True)
class _Pending:
    """One dispatched-but-unharvested device step.

    ``rows`` binds batch rows to their requests *at dispatch time* (slots
    may be re-assigned before the harvest sync).  ``tok`` is the post-step
    (B,) last-token vector; ``routing`` the (L, T, K) device capture (None
    for dense archs / disabled tracking).  ``routing_batch`` is the batch
    size of the dispatched call (1 for the legacy batch-1 prefill, whose
    capture row is always 0).  ``obs_rows`` lists the batch rows whose
    routing capture should feed the tracker (unified mixed batches observe
    mid-prefill rows that sample no token); None = the rows of ``rows``.
    ``stalled`` marks reference-mode prefill dispatched while decode rows
    were in flight (its device time is decode-stall time)."""
    kind: str                     # "prefill" | "decode" | "mixed"
    rows: tuple                   # ((row_in_routing, slot, Request), ...)
    tok: Any
    routing: Any
    routing_batch: int
    obs_rows: tuple | None = None
    stalled: bool = False


class ServingEngine:
    """Continuous-batching engine over the pure-functional Model API."""

    def __init__(self, cfg_model, engine_cfg: EngineConfig | None = None,
                 params=None, rng=None, mesh=None, fault_plan=None):
        self.cfg = cfg_model
        self.ecfg = engine_cfg or EngineConfig()
        self.mesh = mesh
        self.model = build_model(cfg_model)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.params = params if params is not None else self.model.init(rng)
        # quantize-on-load (docs/DESIGN.md §8): convert eligible weight
        # kinds to blockwise QuantTensor leaves BEFORE device placement —
        # the one-time preprocessing step of the weight store (idempotent:
        # params restored from an already-quantized checkpoint pass
        # through untouched; weight_quant="none" is the identity)
        if getattr(cfg_model, "weight_quant", "none") != "none":
            self.params = quant.quantize_params(self.params, cfg_model)
        if mesh is not None:
            from repro.launch import sharding as sharding_lib
            spec = sharding_lib.params_pspec(cfg_model, mesh, self.params,
                                             mode="serve")
            self.params = jax.device_put(
                self.params, sharding_lib.named(mesh, spec))
        self.tracker = (LRUExpertTracker(cfg_model.num_layers,
                                         cfg_model.num_experts)
                        if cfg_model.is_moe and self.ecfg.track_experts
                        else None)
        self.queue = sched.AdmissionQueue()
        self.slots: list[Request | None] = [None] * self.ecfg.max_batch
        # per-slot admission context: the token sequence the occupant is
        # prefilling against — req.prompt on first admission, the longer
        # resume_tokens (prompt + pre-preemption generation) on restore
        self.slot_ctx: list[np.ndarray | None] = [None] * self.ecfg.max_batch
        self._all: dict[int, Request] = {}
        self._uid = 0
        self._seq = 0                 # submission sequence for FIFO ties
        self._iter = 0                # step() count; fault-plan step key
        self._has_deadlines = False   # skip the sweep until one exists
        self._preempt_epoch = 0       # bumps per preemption (fairness key)
        self.preempt_log: list = []   # (iter, uid, running-snapshot) tuples
        self.faults = fault_plan
        if fault_plan is not None and not (self.ecfg.unified_step):
            raise ValueError("fault injection requires the unified engine "
                             "path (unified_step=True)")
        self._guard = (self.ecfg.nan_guard if self.ecfg.nan_guard is not None
                       else fault_plan is not None)
        if self.ecfg.overcommit and not self.ecfg.paged:
            raise ValueError("overcommit requires the paged KV cache "
                             "(EngineConfig.paged=True)")
        b, c = self.ecfg.max_batch, self.ecfg.max_cache
        self.lengths = np.zeros((b,), np.int32)
        self.budgets = np.zeros((b,), np.int32)
        self.last_tok = jnp.zeros((b,), jnp.int32)
        # resilience scratch: the all-clear poison vector (finite = no
        # injection) and the no-guard quarantine answer, built once so
        # the fault-free hot loop allocates nothing per step
        self._poison0 = np.zeros((b,), np.float32)
        self._no_bad = np.zeros((b,), bool)
        self._pending: list[_Pending] = []
        # unified-step scheduler state: per-slot prefill progress (prompt
        # tokens already streamed into the cache) and sampling params
        if self.ecfg.chunk_len < 1 or self.ecfg.token_budget < 0:
            raise ValueError(
                f"chunk_len must be >= 1 and token_budget >= 0, got "
                f"chunk_len={self.ecfg.chunk_len} "
                f"token_budget={self.ecfg.token_budget}")
        # the unified block step needs a token-input attention family and a
        # LINEAR cache: a ring cache (sliding window == cache length) only
        # takes width-1 writes (attention.attn_block_step), so sliding-
        # window archs keep the two-program reference path
        from repro.models.transformer import effective_window
        win = (effective_window(cfg_model, self.ecfg.max_cache)
               if cfg_model.family in ("dense", "moe") else None)
        # transformer.stack_cache_spec clips the cache to the window, so
        # any window <= max_cache means the allocated cache is a ring
        ring = win is not None and win <= self.ecfg.max_cache
        self.unified = (self.ecfg.unified_step and not ring
                        and cfg_model.family in ("dense", "moe"))
        # block width: a chunk can never exceed the cache it streams into
        self.chunk_len = min(self.ecfg.chunk_len, self.ecfg.max_cache)
        # paged KV cache state (EngineConfig.paged; docs/DESIGN.md §7):
        # one donated page pool + host-side allocator / prefix tree /
        # per-slot block tables.  The pool replaces the per-slot cache.
        self.paged = bool(self.ecfg.paged)
        if self.paged:
            if not self.unified:
                raise ValueError(
                    "paged KV cache requires the unified engine path "
                    "(token-input attention family, non-ring cache, "
                    "unified_step=True)")
            if self.ecfg.page_size < 1:
                raise ValueError(
                    f"page_size must be >= 1, got {self.ecfg.page_size}")
            if self.ecfg.paged_kernel and mesh is not None:
                raise ValueError(
                    "paged_kernel is single-host: mesh-sharded serving "
                    "keeps the gather reference path (docs/DESIGN.md §11)")
            self.page_size = self.ecfg.page_size
            self.max_blocks = -(-c // self.page_size)
            self.num_pages = (self.ecfg.num_pages
                              or b * self.max_blocks)
            self.cache = self.model.init_paged_cache(self.num_pages,
                                                     self.page_size)
            self.block_tables = np.zeros((b, self.max_blocks), np.int32)
            self.allocator = PageAllocator(self.num_pages)
            self.prefix = PrefixCache(self.page_size, self.allocator)
            self.slot_pages: list[list[int]] = [[] for _ in range(b)]
            self._jit_copy_pages = jax.jit(
                self._copy_pages,
                donate_argnums=(0,) if self.ecfg.donate_buffers else ())
        elif self.ecfg.paged_kernel:
            raise ValueError(
                "paged_kernel requires paged=True (it attends through "
                "the page pool's block tables)")
        else:
            self.cache = self.model.init_cache(b, c)
        self.prefill_pos = np.zeros((b,), np.int64)
        self.temps = np.zeros((b,), np.float32)
        self.topks = np.zeros((b,), np.int32)
        self._sample_key = jax.random.PRNGKey(self.ecfg.sample_seed)
        self._step_idx = 0
        self._admit_stalled = False
        # Retrace accounting (analysis rule R3): each jit body bumps its
        # counter at TRACE time, so this Counter records how many programs
        # XLA specialized since engine birth.  The documented steady-state
        # set: unified traces at widths chunk_len and 1 (the pure-decode
        # block), reference mode traces prefill once and decode once, paged
        # mode adds one copy_pages trace, and flipping the static sampling
        # flag doubles each — anything beyond that is a silent recompile
        # eating dispatch latency.  Note ``.lower()`` on a jit also traces.
        self.trace_counts: collections.Counter = collections.Counter()
        # cache is argument 1 of every jit body; self.cache is rebound to the
        # output before the next dispatch, so donating it is always safe.
        donate = (1,) if self.ecfg.donate_buffers else ()
        # the trailing ``sampling`` flag is STATIC: greedy-only workloads
        # trace a pure-argmax program; the first stochastic submit() flips
        # the flag and retraces once with the Gumbel/top-k sampler inlined
        self._jit_prefill_batch = jax.jit(self._prefill_batch,
                                          donate_argnums=donate,
                                          static_argnums=(8,))
        self._jit_prefill_one = jax.jit(self._prefill_one,
                                        donate_argnums=donate,
                                        static_argnums=(8,))
        self._jit_decode = jax.jit(self._decode, donate_argnums=donate,
                                   static_argnums=(8,))
        self._jit_unified = jax.jit(self._unified, donate_argnums=donate,
                                    static_argnums=(13,))
        self._sampling = False
        self.stats = {"prefill_tokens": 0, "prefill_pad_tokens": 0,
                      "decode_steps": 0, "decode_tokens": 0,
                      # per-phase token counts of MIXED iterations only —
                      # throughput() apportions mixed_s by their share
                      # (satellite fix: mixed time was double-counted in
                      # both per-phase denominators)
                      "mixed_prefill_tokens": 0, "mixed_decode_tokens": 0,
                      "prefill_s": 0.0, "decode_s": 0.0, "mixed_s": 0.0,
                      "stall_s": 0.0, "harvest_s": 0.0, "harvests": 0,
                      # paged-mode counters (0 when paged=False)
                      "prefix_lookups": 0, "prefix_hits": 0,
                      "prefix_hit_tokens": 0, "cow_copies": 0,
                      "pages_hwm": 0,
                      # resilience counters (docs/DESIGN.md §10)
                      "preemptions": 0, "restores": 0,
                      "restore_hit_tokens": 0, "cancelled": 0,
                      "expired": 0, "failed": 0,
                      "alloc_stalls": 0, "dispatch_failures": 0,
                      "nan_quarantines": 0, "active_hwm": 0}

    # -- jit bodies ---------------------------------------------------------

    def _sample_next(self, logits: Array, temps: Array, topks: Array,
                     step_idx: Array, sampling: bool) -> Array:
        """Per-row sampling inside the jit step: greedy argmax where
        temperature == 0 (the default, keeping every token-equality gate
        exact), otherwise temperature-scaled top-k Gumbel sampling with an
        RNG folded on (engine step, slot) so replays with the same
        ``sample_seed`` are deterministic.

        ``sampling`` is a TRACE-TIME flag (static jit argument): it stays
        False until the first stochastic request is submitted, so purely
        greedy workloads never trace the (B, V) sort / Gumbel draws into
        the hot loop — the all-greedy program is pure argmax.

        logits: (B, V_padded) fp32; temps: (B,) fp32; topks: (B,) int32
        (0 = full vocab); step_idx: () int32."""
        v = self.cfg.vocab_size
        logits = logits[:, :v].astype(jnp.float32)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if not sampling:
            return greedy
        b = logits.shape[0]
        key = jax.random.fold_in(self._sample_key, step_idx)
        keys = jax.vmap(jax.random.fold_in, (None, 0))(key, jnp.arange(b))
        gum = jax.vmap(lambda k: jax.random.gumbel(k, (v,), jnp.float32))(keys)
        scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
        kth = jnp.take_along_axis(
            -jnp.sort(-scaled, axis=-1),                  # descending sort
            (jnp.clip(topks, 1, v) - 1)[:, None], axis=-1)
        keep = (scaled >= kth) | (topks[:, None] <= 0)
        samp = jnp.argmax(jnp.where(keep, scaled, -1e30) + gum,
                          axis=-1).astype(jnp.int32)
        return jnp.where(temps > 0, samp, greedy)

    def _unified(self, params, cache, tokens, last_tok, lengths, seg_lens,
                 block_tables, is_decode, sample_mask, temps, topks,
                 poison, step_idx, sampling):
        """ONE jit program for prefill chunks, decode rows, and any mix.

        tokens: (B, chunk_len) host-scheduled block — decode rows take their
        input token from device-resident ``last_tok`` instead (column 0), so
        the decode feedback loop never syncs to the host.  ``seg_lens``
        gives each row's valid-token count at cache offset ``lengths``;
        ``sample_mask`` marks rows whose last valid logit becomes a
        generated token (decode rows and final prefill chunks — mid-prompt
        chunks keep ``last_tok`` untouched).  ``block_tables`` is None on
        the contiguous cache and the (B, max_blocks) page map on the paged
        pool (an undonated host snapshot, like ``lengths``).

        ``poison`` is the fault-injection vector (serving/faults.py): a
        (B,) fp32 whose non-finite entries overwrite that row's logits
        (finite entries — the steady state — inject nothing; the vector is
        a runtime value, so injection never retraces).  The step always
        returns a per-row ``bad`` finiteness flag and refuses to let a
        non-finite row overwrite ``last_tok`` — the device half of the
        NaN quarantine, active whether or not the host guard reads it.

        Returns (last_tok', cache', routing (L, B*chunk_len, K),
        bad (B,) bool)."""
        self.trace_counts["unified"] += 1
        tok0 = jnp.where(is_decode, last_tok, tokens[:, 0])
        tokens = jnp.concatenate([tok0[:, None], tokens[:, 1:]], axis=1)
        # context_len pins the windowing decision to the LOGICAL context
        # (max_cache) in both layouts: the paged pool's block-table reach
        # rounds up to whole pages, and letting effective_window() see the
        # rounded value could flip the long-context SWA variant on in
        # paged mode but not contiguous — breaking token equality exactly
        # at ragged page sizes
        logits, cache, routing = self.model.forward_routed(
            params, {"tokens": tokens, "lengths": lengths,
                     "seg_lens": seg_lens, "block_tables": block_tables},
            cache, self.mesh, context_len=self.ecfg.max_cache,
            paged_kernel=self.ecfg.paged_kernel)
        logits = jnp.where(jnp.isfinite(poison)[:, None], logits,
                           poison[:, None].astype(logits.dtype))
        bad = ~jnp.all(jnp.isfinite(
            logits[:, :self.cfg.vocab_size].astype(jnp.float32)), axis=-1)
        nxt = self._sample_next(logits, temps, topks, step_idx, sampling)
        last_tok = jnp.where(sample_mask & ~bad, nxt, last_tok)
        return last_tok, cache, routing, bad

    def _copy_pages(self, cache, src, dst):
        """Device half of copy-on-write (serving/paging): duplicate pool
        pages ``src`` into ``dst`` across every layer and cache leaf.  The
        copy moves ``n * page_size`` rows — page-sized traffic, never a
        pool-sized buffer — and the pool stays donated/aliased."""
        self.trace_counts["copy_pages"] += 1
        return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), cache)

    def _prefill_batch(self, params, cache, tokens, admit_mask, last_tok,
                       temps, topks, step_idx, sampling):
        """Admit up to max_batch requests in ONE call.

        tokens: (B, prefill_len) — zeros on non-admitted rows;
        admit_mask: (B,) bool.  The full-batch prefill recomputes every row
        (static shapes, one XLA program); the cache is then merged row-wise
        so in-flight slots keep their state.  Returns (last_tok', cache',
        routing) with last_tok' holding each admitted row's first sampled
        token."""
        self.trace_counts["prefill_batch"] += 1
        tmask = jnp.broadcast_to(admit_mask[:, None], tokens.shape)
        logits, new_cache, routing = self.model.prefill_routed(
            params, {"tokens": tokens, "token_mask": tmask}, cache, self.mesh)
        nxt = self._sample_next(logits[:, -1], temps, topks, step_idx,
                                sampling)

        def merge(old, new):
            if old.ndim < 2:      # scalar bookkeeping leaves, if any
                return new
            m = admit_mask.reshape((1, old.shape[1]) + (1,) * (old.ndim - 2))
            return jnp.where(m, new, old)

        cache = jax.tree.map(merge, cache, new_cache)
        last_tok = jnp.where(admit_mask, nxt, last_tok)
        return last_tok, cache, routing

    def _prefill_one(self, params, cache, tokens, slot, last_tok,
                     temps, topks, step_idx, sampling):
        """Legacy reference path: batch-1 prefill scattered into ``slot``.

        The batch-1 working cache is *sliced* out of the full cache rather
        than zero-materialized: the old ``jnp.zeros`` + scatter pattern
        allocated a fresh per-slot cache copy every admit, while the slice
        reads one row and (under donation) scatters it back in place.
        Prefill overwrites the whole prompt region and decode masks by
        ``lengths``, so any stale tail beyond the prompt is never attended —
        the same invariant the batched path relies on when it recomputes
        in-flight rows under the admit mask."""
        self.trace_counts["prefill_one"] += 1
        one_cache = jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1)
            if a.ndim >= 2 else a, cache)
        logits, one_cache, routing = self.model.prefill_routed(
            params, {"tokens": tokens}, one_cache, self.mesh)
        cache = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_index_in_dim(
                full, one[:, 0], slot, axis=1), cache, one_cache)
        nxt = self._sample_next(logits[:, -1], jnp.take(temps, slot)[None],
                                jnp.take(topks, slot)[None], step_idx,
                                sampling)  # (1,)
        last_tok = jax.lax.dynamic_update_index_in_dim(
            last_tok, nxt[0], slot, axis=0)
        return last_tok, cache, routing

    def _decode(self, params, cache, last_tok, lengths, active_mask,
                temps, topks, step_idx, sampling):
        self.trace_counts["decode"] += 1
        logits, cache, routing = self.model.decode_step_routed(
            params, cache, {"tokens": last_tok[:, None], "lengths": lengths,
                            "token_mask": active_mask[:, None]},
            self.mesh)
        nxt = self._sample_next(logits[:, -1], temps, topks, step_idx,
                                sampling)
        last_tok = jnp.where(active_mask, nxt, last_tok)
        return last_tok, cache, routing

    # -- public API ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               temperature: float = 0.0, top_k: int = 0,
               priority: int = 0, deadline_ms: float | None = None) -> int:
        """Queue a request.  ``temperature``/``top_k`` select per-request
        sampling inside the jit step (greedy when temperature=0).

        ``priority`` orders admission (higher first, FIFO within a class;
        under ``EngineConfig.overcommit`` a higher-priority arrival may
        preempt strictly-lower-priority running rows).  ``deadline_ms``
        is a wall-clock budget from submit: a request still unfinished
        when it elapses is expired and its pages released.

        Prompt-length contract: the unified engine streams prompts through
        the cache in chunks, so anything up to ``max_cache`` is served
        without truncation; the reference (``unified_step=False``) path
        pads whole prompts to ``prefill_len`` and REJECTS longer ones
        instead of silently dropping the prefix (the seed engine's
        behaviour)."""
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) == 0:
            # a zero-length prompt has no defined context: the unified
            # scheduler would classify it as a decode row seeded from the
            # slot's STALE last_tok (the previous occupant's final token)
            raise ValueError("empty prompt")
        limit = (self.ecfg.max_cache if self.unified
                 else self.ecfg.prefill_len)
        if len(prompt) > limit:
            mode = "unified" if self.unified else "reference"
            raise ValueError(
                f"prompt of {len(prompt)} tokens exceeds the {mode} "
                f"engine's limit of {limit} "
                f"({'max_cache' if self.unified else 'prefill_len'}); "
                f"refusing to silently truncate")
        # decode step i writes generated token i at slot context+i; past
        # max_cache those writes clamp/drop and later tokens are generated
        # against a context missing their predecessors — reject instead of
        # silently corrupting.  The reference path always decodes from
        # offset prefill_len (the padded program), the unified path from
        # the real prompt length.
        context = len(prompt) if self.unified else self.ecfg.prefill_len
        if context + max_new_tokens - 1 > self.ecfg.max_cache:
            raise ValueError(
                f"context of {context} tokens + {max_new_tokens} new "
                f"tokens does not fit the {self.ecfg.max_cache}-slot cache; "
                f"lower max_new_tokens or raise max_cache")
        if self.paged:
            blocks = -(-(context + max_new_tokens - 1) // self.page_size)
            if blocks > self.num_pages:
                raise ValueError(
                    f"request needs {blocks} pages but the pool holds only "
                    f"{self.num_pages}; raise num_pages or lower "
                    f"max_new_tokens")
        self._uid += 1
        self._seq += 1
        if temperature > 0:
            self._sampling = True    # one-time retrace with the sampler
        now = time.perf_counter()
        req = Request(self._uid, prompt, max_new_tokens,
                      temperature=float(temperature), top_k=int(top_k),
                      priority=int(priority), submit_s=now, seq=self._seq,
                      deadline_s=(now + deadline_ms / 1e3
                                  if deadline_ms is not None else None))
        if req.deadline_s is not None:
            self._has_deadlines = True
        self.queue.append(req)
        self._all[req.uid] = req
        return self._uid

    def _pad_prompt(self, req: Request) -> np.ndarray:
        assert len(req.prompt) <= self.ecfg.prefill_len  # enforced at submit
        pad = np.zeros((self.ecfg.prefill_len,), np.int32)
        pad[:len(req.prompt)] = req.prompt
        return pad

    def _admit(self) -> None:
        free = [i for i, r in enumerate(self.slots) if r is None]
        if not free or not self.queue:
            return
        # reference-mode stall: any in-flight decode slot sits idle for the
        # whole separate prefill program (the unified path has no such
        # window — decode rows ride every iteration)
        self._admit_stalled = any(r is not None for r in self.slots)
        if self.ecfg.batched_prefill:
            self._admit_batched(free)
        else:
            self._admit_sequential(free)

    def _post_admit(self, rows, routing, routing_batch: int) -> None:
        for _, slot, req in rows:
            self.slots[slot] = req
            self.slot_ctx[slot] = req.prompt
            req.status = "running"
            self.lengths[slot] = self.ecfg.prefill_len
            self.budgets[slot] = req.max_new_tokens - 1
            # real prompt tokens vs the padding the fixed-length program
            # recomputes anyway (satellite fix: tok/s counts real work)
            self.stats["prefill_tokens"] += len(req.prompt)
            self.stats["prefill_pad_tokens"] += (self.ecfg.prefill_len
                                                 - len(req.prompt))
        self._pending.append(_Pending("prefill", tuple(rows), self.last_tok,
                                      routing, routing_batch,
                                      stalled=self._admit_stalled))
        if not self.ecfg.async_steps:
            self._harvest()

    def _admit_batched(self, free: list[int]) -> None:
        rows = []
        tokens = np.zeros((self.ecfg.max_batch, self.ecfg.prefill_len),
                          np.int32)
        admit = np.zeros((self.ecfg.max_batch,), bool)
        for slot in free:
            if not self.queue:
                break
            req = self.queue.popleft()
            tokens[slot] = self._pad_prompt(req)
            admit[slot] = True
            self.temps[slot] = req.temperature
            self.topks[slot] = req.top_k
            rows.append((slot, slot, req))
        t0 = time.perf_counter()
        step_idx = self._next_step_idx()
        # tokens/admit are freshly built per call and never mutated after
        # dispatch (see the transfer note in step())
        self.last_tok, self.cache, routing = self._jit_prefill_batch(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(admit),
            self.last_tok, jnp.asarray(self.temps.copy()),
            jnp.asarray(self.topks.copy()), step_idx, self._sampling)
        if not self.ecfg.async_steps:
            self.last_tok.block_until_ready()
        dt = time.perf_counter() - t0
        self.stats["prefill_s"] += dt
        if self._admit_stalled:
            self.stats["stall_s"] += dt
        self._post_admit(rows, routing, self.ecfg.max_batch)

    def _admit_sequential(self, free: list[int]) -> None:
        for slot in free:
            if not self.queue:
                break
            # re-check per dispatch: request N's separate prefill program
            # stalls the requests admitted earlier in this same round too
            self._admit_stalled = any(r is not None for r in self.slots)
            req = self.queue.popleft()
            tokens = self._pad_prompt(req)[None]
            self.temps[slot] = req.temperature
            self.topks[slot] = req.top_k
            t0 = time.perf_counter()
            step_idx = self._next_step_idx()
            self.last_tok, self.cache, routing = self._jit_prefill_one(
                self.params, self.cache, jnp.asarray(tokens), slot,
                self.last_tok, jnp.asarray(self.temps.copy()),
                jnp.asarray(self.topks.copy()), step_idx, self._sampling)
            if not self.ecfg.async_steps:
                self.last_tok.block_until_ready()
            dt = time.perf_counter() - t0
            self.stats["prefill_s"] += dt
            if self._admit_stalled:
                self.stats["stall_s"] += dt
            self._post_admit([(0, slot, req)], routing, 1)

    def _next_step_idx(self) -> Any:
        """Monotone per-dispatch counter feeding the sampling RNG fold
        (handed to the jit as a 0-d device array so it traces once)."""
        i = self._step_idx
        self._step_idx += 1
        return jnp.asarray(i, jnp.int32)

    def step(self) -> int:
        """One engine iteration.  Returns the number of rows that did work.

        Unified mode: admit (state-only), then pack decode rows + prefill
        chunks into ONE mixed-batch jit call (``_step_unified``).
        Reference mode: admit (separate whole-prompt prefill programs,
        stalling in-flight decodes) + one decode step.

        In async mode the device step is only *dispatched* here; tokens are
        appended to requests at the next harvest boundary (a request
        finishing, ``flush()``, or sync mode)."""
        self._iter += 1
        self._sweep_deadlines()
        if self.unified:
            return self._step_unified()
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        mask = np.zeros((self.ecfg.max_batch,), bool)
        mask[active] = True
        t0 = time.perf_counter()
        step_idx = self._next_step_idx()
        # NB: self.lengths is handed to the device as a host-side SNAPSHOT
        # (.copy()) that nothing mutates afterwards.  The host→device
        # transfer is itself deferred on jaxlib 0.4.x CPU — even
        # jnp.array's copy can read the source buffer *after* the
        # `self.lengths[i] += 1` below, which under CPU load produced
        # stale-length decodes (KV written over the previous slot,
        # repeated tokens).  mask/tokens buffers are freshly built per
        # call and never mutated after dispatch, so they are safe as-is.
        self.last_tok, self.cache, routing = self._jit_decode(
            self.params, self.cache, self.last_tok,
            jnp.asarray(self.lengths.copy()), jnp.asarray(mask),
            jnp.asarray(self.temps.copy()), jnp.asarray(self.topks.copy()),
            step_idx, self._sampling)
        if not self.ecfg.async_steps:
            self.last_tok.block_until_ready()
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_steps"] += 1
        rows = tuple((i, i, self.slots[i]) for i in active)
        self._pending.append(_Pending("decode", rows, self.last_tok, routing,
                                      self.ecfg.max_batch))
        finishing = False
        for i in active:
            self.lengths[i] = min(self.lengths[i] + 1, self.ecfg.max_cache)
            self.stats["decode_tokens"] += 1
            self.budgets[i] -= 1
            if self.budgets[i] <= 0:
                # budget-based completion is host-known at dispatch time:
                # free the slot now, collect the tokens at the harvest below
                self._finish_slot(i)
                finishing = True
        if finishing or not self.ecfg.async_steps:
            self._harvest()
        return len(active)

    # -- unified token-budget iteration -------------------------------------

    def _step_unified(self) -> int:
        """One token-budget iteration of the unified engine.

        Admission only binds a request to a slot (no device work), so
        arrivals NEVER stall in-flight decode rows.  The iteration then
        schedules, in one (max_batch, chunk_len) block at per-row cache
        offsets: (a) every decode row — one token each, exempt from the
        budget; (b) pending prefill chunks, oldest slot first, until
        ``token_budget`` (0 = unlimited) is exhausted.  A row whose chunk
        completes its prompt samples its first generated token from that
        chunk's last logit — the prefill→decode transition costs no extra
        program.

        Resilience hooks (docs/DESIGN.md §10): paged decode rows secure
        the page their next token writes BEFORE anything is scheduled
        (lazy growth under overcommit — a row that cannot get one either
        idles for the iteration or preempts a peer); an injected
        dispatch fault aborts the iteration before any host bookkeeping
        mutates, so the identical iteration re-dispatches next step; and
        with the NaN guard on, rows whose logits came back non-finite
        are withheld from every host-state advance (lengths /
        prefill_pos / budgets / token record) and retried from their
        last durable cache state — the re-dispatched block writes are
        idempotent, so neighbours never see the fault."""
        b, t = self.ecfg.max_batch, self.chunk_len
        for i in range(b):
            if self.slots[i] is None and self.queue:
                if self.paged:
                    # page-gated admission: priority order, stop at the
                    # first request the pool cannot hold (never skip
                    # ahead within the queue)
                    if not self._admit_paged(i):
                        break
                    continue
                req = self.queue.popleft()
                self.slots[i] = req
                self.slot_ctx[i] = req.prompt
                req.status = "running"
                self.lengths[i] = 0
                self.prefill_pos[i] = 0
                self.budgets[i] = req.max_new_tokens
                self.temps[i] = req.temperature
                self.topks[i] = req.top_k
        self.stats["active_hwm"] = max(
            self.stats["active_hwm"],
            sum(1 for s in self.slots if s is not None))
        if self.paged:
            # lazy-growth pass: every decode-phase row secures the page
            # its next token writes BEFORE any row enters this
            # iteration's dispatch — growth may preempt a peer (or the
            # grower itself), and a preempted row must never already be
            # scheduled when its pages are released
            for i in range(b):
                req = self.slots[i]
                if (req is not None
                        and self.prefill_pos[i] >= len(self.slot_ctx[i])):
                    self._ensure_decode_page(i)
        tokens = np.zeros((b, t), np.int32)
        seg = np.zeros((b,), np.int32)
        is_dec = np.zeros((b,), bool)
        sample = np.zeros((b,), bool)
        budget = self.ecfg.token_budget or (b * t + b)   # 0 = unlimited
        decode_rows, prefill_rows = [], []
        for i, req in enumerate(self.slots):
            if req is not None and self.prefill_pos[i] >= len(self.slot_ctx[i]):
                if self.paged and not self._covered(i):
                    # page-starved (alloc fault / exhausted pool with no
                    # victim): the row idles this iteration with all its
                    # state intact and retries next step
                    continue
                seg[i] = 1
                is_dec[i] = sample[i] = True
                decode_rows.append(i)   # budget-exempt: decode never starves
        for i, req in enumerate(self.slots):
            if req is None or is_dec[i] or budget <= 0:
                continue
            ctx = self.slot_ctx[i]
            pos = int(self.prefill_pos[i])
            n = min(t, len(ctx) - pos, budget,
                    self.ecfg.max_cache - int(self.lengths[i]))
            if n <= 0:
                continue
            tokens[i, :n] = ctx[pos:pos + n]
            seg[i] = n
            budget -= n
            sample[i] = pos + n == len(ctx)
            prefill_rows.append(i)
        if not decode_rows and not prefill_rows:
            return 0
        # decode-only iterations shrink the block to width 1: the unified
        # program is length-agnostic, so the same jit body retraces once at
        # (B, 1) and the steady-state decode iteration costs exactly a
        # decode step — never chunk_len columns of dead compute
        if not prefill_rows:
            tokens = tokens[:, :1]
        poison = self._poison0
        if self.faults is not None:
            f = self.faults.poll(self._iter, "nan")
            if f is not None:
                poison = poison.copy()
                poison[list(f.rows) if f.rows else range(b)] = f.value
        t0 = time.perf_counter()
        # lengths/temps/topks/block-table snapshots: same deferred-transfer
        # race rule as the reference decode path (see step())
        bt = (jnp.asarray(self.block_tables.copy()) if self.paged else None)
        try:
            if self.faults is not None:
                # raised in place of the backend failing the launch:
                # nothing host-side has mutated yet (not even the RNG
                # step index), so the identical iteration re-dispatches
                # on the next step()
                self.faults.maybe_raise(self._iter, "dispatch")
            step_idx = self._next_step_idx()
            out = self._jit_unified(
                self.params, self.cache, jnp.asarray(tokens), self.last_tok,
                jnp.asarray(self.lengths.copy()), jnp.asarray(seg), bt,
                jnp.asarray(is_dec), jnp.asarray(sample),
                jnp.asarray(self.temps.copy()),
                jnp.asarray(self.topks.copy()), jnp.asarray(poison),
                step_idx, self._sampling)
        except InjectedFault:
            self.stats["dispatch_failures"] += 1
            return 0
        self.last_tok, self.cache, routing, bad = out
        if not self.ecfg.async_steps:
            self.last_tok.block_until_ready()
        dt = time.perf_counter() - t0
        kind = ("decode" if not prefill_rows
                else "prefill" if not decode_rows else "mixed")
        self.stats[{"decode": "decode_s", "prefill": "prefill_s",
                    "mixed": "mixed_s"}[kind]] += dt
        if kind == "mixed":
            # per-phase token counts so throughput() can apportion
            # mixed_s by token share instead of double-counting it
            self.stats["mixed_decode_tokens"] += len(decode_rows)
            self.stats["mixed_prefill_tokens"] += int(
                sum(int(seg[i]) for i in prefill_rows))
        bad_host = (self._quarantine_check(bad) if self._guard
                    else self._no_bad)
        rows = []
        finishing = False
        for i in decode_rows:
            if bad_host[i]:
                finishing |= self._quarantine(i)
                continue
            self.slots[i].nan_retries = 0
            self.lengths[i] = min(self.lengths[i] + 1, self.ecfg.max_cache)
            self.stats["decode_tokens"] += 1
            self.budgets[i] -= 1
            rows.append((i, i, self.slots[i]))
            if self.budgets[i] <= 0:
                self._finish_slot(i)
                finishing = True
        if decode_rows:
            self.stats["decode_steps"] += 1
        for i in prefill_rows:
            if bad_host[i]:
                finishing |= self._quarantine(i)
                continue
            self.slots[i].nan_retries = 0
            n = int(seg[i])
            self.lengths[i] += n
            self.prefill_pos[i] += n
            self.stats["prefill_tokens"] += n
            if sample[i]:                 # prompt complete: token 1 sampled
                if self.paged:
                    # the prompt's pages are final from this dispatch on:
                    # cache them for prefix reuse BEFORE any release
                    self._prefix_insert(i)
                rows.append((i, i, self.slots[i]))
                self.budgets[i] -= 1
                if self.budgets[i] <= 0:
                    self._finish_slot(i)
                    finishing = True
        self._pending.append(_Pending(
            kind, tuple(rows), self.last_tok, routing, b,
            obs_rows=tuple(i for i in range(b)
                           if seg[i] and not bad_host[i])))
        if finishing or not self.ecfg.async_steps:
            self._harvest()
        return len(decode_rows) + len(prefill_rows)

    # -- paged-cache bookkeeping (EngineConfig.paged; docs/DESIGN.md §7) ----

    def _admit_paged(self, slot: int) -> bool:
        """Map the queue head into ``slot`` if the pool can hold its page
        entitlement, minus every page shared through the prefix cache.

        Entitlement: the whole lifetime — ceil((context + remaining_new
        - 1) / page_size) blocks — by default (PR4's conservative
        admission: an admitted request can never hit pool OOM
        mid-generation), or only the CONTEXT's pages under
        ``EngineConfig.overcommit``, where lazy decode growth
        (``_ensure_decode_page``) takes the rest one page at a time.

        Restore is the same operation (docs/DESIGN.md §10): a preempted
        request's ``resume_tokens`` (prompt + everything generated
        before preemption) is its context, and its own evicted pages ARE
        the prefix hit — so restore is a block-table remap plus at most
        one partial-tail re-prefill chunk, and the greedy token stream
        continues exactly where it stopped.  Under overcommit a short
        pool preempts strictly-lower-priority running rows (least
        recently preempted first) until the head fits or nobody lesser
        remains.

        Returns False with the queue untouched (priority order
        preserved) when pages stay short even after LRU eviction and
        preemption."""
        req = self.queue[0]
        if self.faults is not None and self.faults.poll(self._iter, "alloc"):
            # injected pool exhaustion: admission sees nothing free and
            # nothing reclaimable this iteration — the request just
            # stays queued (no refcount was taken)
            self.stats["alloc_stalls"] += 1
            return False
        ctx = (req.resume_tokens if req.resume_tokens is not None
               else req.prompt)
        remaining = req.max_new_tokens - len(req.generated)
        lifetime = sched.lifetime_pages(len(ctx), remaining, self.page_size)
        upfront = (sched.pages_for(len(ctx), self.page_size)
                   if self.ecfg.overcommit else lifetime)
        hit = self.prefix.lookup(ctx)
        need = upfront - len(hit.pages)
        if self.allocator.free_pages < need:
            # evict only when it can actually close the gap: a request
            # merely waiting for in-flight pages must NOT drain the tree
            # (it retries every iteration — unconditional eviction would
            # destroy the cached system prompt while freeing nothing)
            if (self.allocator.free_pages + self.prefix.reclaimable_pages()
                    >= need):
                self.prefix.evict(need)
        while self.allocator.free_pages < need and self.ecfg.overcommit:
            # priority preemption: a victim's pages land in the prefix
            # tree (reclaimable once its row references drop), so each
            # preemption is followed by another gap-closing eviction
            victim = sched.select_victim(self._running_rows(),
                                         below=req.priority)
            if victim is None:
                break
            self._preempt_slot(victim)
            if (self.allocator.free_pages + self.prefix.reclaimable_pages()
                    >= need):
                self.prefix.evict(need)
        if self.allocator.free_pages < need:
            # hand the lookup references back; the request stays queued
            # (retried next iteration — not counted as a prefix lookup,
            # so hit-rate stats count requests, not retries)
            self.allocator.free(hit.pages)
            if hit.tail_page >= 0:
                self.allocator.free([hit.tail_page])
            return False
        self.stats["prefix_lookups"] += 1
        new_pages = self.allocator.alloc(need)
        pages = list(hit.pages) + new_pages
        if hit.tail_len:
            # copy-on-write the shared partial tail page: its owner may
            # still be appending decode tokens to the original, so this
            # request copies the page (one page-sized device op) and
            # overwrites the divergent suffix as it writes
            dst = new_pages[0]
            self.cache = self._jit_copy_pages(
                self.cache, jnp.asarray([hit.tail_page], jnp.int32),
                jnp.asarray([dst], jnp.int32))
            self.allocator.free([hit.tail_page])   # drop the lookup ref
            self.stats["cow_copies"] += 1
        self.queue.popleft()
        self.slots[slot] = req
        self.slot_ctx[slot] = ctx
        self.slot_pages[slot] = pages
        self.block_tables[slot] = 0
        self.block_tables[slot, :len(pages)] = pages
        # the shared prefix is already in the cache: prefill starts at
        # hit.tokens, skipping exactly that much prefill work
        self.lengths[slot] = hit.tokens
        self.prefill_pos[slot] = hit.tokens
        self.budgets[slot] = remaining
        self.temps[slot] = req.temperature
        self.topks[slot] = req.top_k
        if req.status == "preempted":
            self.stats["restores"] += 1
            self.stats["restore_hit_tokens"] += hit.tokens
        req.status = "running"
        if hit.tokens:
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += hit.tokens
        self.stats["pages_hwm"] = max(self.stats["pages_hwm"],
                                      self.allocator.pages_in_use)
        return True

    def _covered(self, i: int) -> bool:
        """Row ``i``'s next decode write (cache position ``lengths[i]``)
        has a page under its block table."""
        return int(self.lengths[i]) < len(self.slot_pages[i]) * self.page_size

    def _running_rows(self) -> list:
        """Victim candidates for sched.select_victim: every occupied
        slot with its scheduling keys."""
        return [sched.RunningRow(i, r.priority, r.last_preempt_epoch, r.seq)
                for i, r in enumerate(self.slots) if r is not None]

    def _ensure_decode_page(self, i: int) -> bool:
        """Lazy decode-page growth (docs/DESIGN.md §10): make sure row
        ``i``'s block table covers the position its next token writes.

        Whole-lifetime admission always covers it (the fast path).  An
        overcommitted row takes one page at a time: evict LRU prefix
        entries if that closes the gap; if the pool is still dry,
        preempt the least-entitled running row — possibly row ``i``
        itself, which then yields instead of starving a peer.  Returns
        False when the row cannot advance this iteration (preempted, or
        page-starved under an injected alloc fault / a pool with no
        eligible victim)."""
        if self._covered(i):
            return True
        if self.faults is not None and self.faults.poll(self._iter, "alloc"):
            self.stats["alloc_stalls"] += 1
            return False
        if (self.allocator.free_pages < 1
                and self.allocator.free_pages
                + self.prefix.reclaimable_pages() >= 1):
            self.prefix.evict(1)
        while self.allocator.free_pages < 1 and self.ecfg.overcommit:
            victim = sched.select_victim(self._running_rows())
            if victim is None:
                break
            self._preempt_slot(victim)
            if victim == i:
                return False
            if (self.allocator.free_pages
                    + self.prefix.reclaimable_pages() >= 1):
                self.prefix.evict(1)
        got = self.allocator.alloc(1)
        if got is None:
            self.stats["alloc_stalls"] += 1
            return False
        self.slot_pages[i].append(got[0])
        self.block_tables[i, len(self.slot_pages[i]) - 1] = got[0]
        self.stats["pages_hwm"] = max(self.stats["pages_hwm"],
                                      self.allocator.pages_in_use)
        return True

    def _preempt_slot(self, i: int) -> None:
        """Evict row ``i`` to the prefix cache and requeue its request —
        the preemption protocol (docs/DESIGN.md §10).

        Order matters: (1) harvest, so every in-flight token of the row
        is on the host and the virtual prompt (prompt + generated) is
        final; (2) insert the row's durable cache state — ``lengths[i]``
        tokens: every full page plus the partial tail — into the prefix
        tree as an ordinary entry; (3) free the row's own page
        references (the tree's references keep the state alive,
        LRU-evictable under later pressure); (4) requeue with
        ``resume_tokens`` = the virtual prompt and the ORIGINAL
        submission seq, so the request re-enters ahead of later
        same-priority arrivals.  Restore (``_admit_paged``) then finds
        its own pages as a prefix hit and re-prefills at most one
        partial chunk: greedy token streams are identical to the
        unpreempted run."""
        self._harvest()
        req = self.slots[i]
        full = np.concatenate([req.prompt,
                               np.asarray(req.generated, np.int32)])
        n = int(self.lengths[i])
        ps = self.page_size
        k, tail = n // ps, n % ps
        pages = self.slot_pages[i]
        if n:
            self.prefix.insert(full[:n], pages[:k],
                               pages[k] if tail else -1, tail)
        self.allocator.free(pages)
        self.slot_pages[i] = []
        self.block_tables[i] = 0
        self.slots[i] = None
        self.slot_ctx[i] = None
        self._preempt_epoch += 1
        req.resume_tokens = full
        req.status = "preempted"
        req.preemptions += 1
        req.last_preempt_epoch = self._preempt_epoch
        self.stats["preemptions"] += 1
        self.preempt_log.append(
            (self._iter, req.uid,
             tuple((r.uid, r.priority) for r in self.slots
                   if r is not None)))
        self.queue.append(req)

    def preempt(self, uid: int) -> bool:
        """Preempt the running request ``uid`` now (public policy hook;
        also how analysis R3's drive_engine pushes a preemption through
        the trace-budget audit).  Its pages move into the prefix tree
        and the request restores through normal admission.  Returns
        False if ``uid`` is not currently in a slot."""
        if not self.paged:
            raise ValueError("preemption requires the paged KV cache "
                             "(EngineConfig.paged=True)")
        for i, r in enumerate(self.slots):
            if r is not None and r.uid == uid:
                self._preempt_slot(i)
                return True
        return False

    def _release_slot(self, i: int) -> None:
        """Free slot ``i``'s pages and binding (exactly once: the page
        list is emptied, so a second call is a no-op).  Paged mode drops
        the request's page references — pages the prefix tree also holds
        stay resident for future hits; the rest return to the free
        list."""
        if self.paged and self.slot_pages[i]:
            self.allocator.free(self.slot_pages[i])
            self.slot_pages[i] = []
            self.block_tables[i] = 0
        self.slots[i] = None
        self.slot_ctx[i] = None

    def _finish_slot(self, i: int) -> None:
        """Normal completion: the budget is exhausted and the final token
        is already in flight to the harvest (which flips ``done`` when
        the token count lands)."""
        req = self.slots[i]
        if req.status == "running":
            req.status = "done"
        self._release_slot(i)

    def _prefix_insert(self, i: int) -> None:
        """Record row ``i``'s freshly prefilled context in the prefix tree
        (called when its prefill completes — the pages' contents are final
        from that dispatch on, in dispatch order).  Full page-aligned
        chunks become radix nodes; a non-aligned remainder becomes the
        node's partial-tail record, shareable via copy-on-write.  For a
        restored request the context is ``resume_tokens`` (prompt + the
        pre-preemption generation), so its re-entered state is shareable
        too."""
        ctx = self.slot_ctx[i]
        ps = self.page_size
        k = len(ctx) // ps
        pages = [int(p) for p in self.block_tables[i, :k]]
        tail_len = len(ctx) - k * ps
        tail_page = int(self.block_tables[i, k]) if tail_len else -1
        self.prefix.insert(ctx, pages, tail_page, tail_len)

    # -- cancellation, deadlines, quarantine (docs/DESIGN.md §10) -----------

    def _terminate_req(self, req: Request, status: str) -> None:
        """Move ``req`` to a terminal state (its pages must already be
        released).  ``done`` flips so waiters see it finished; ``status``
        says why."""
        req.status = status
        req.done = True
        self.stats[status] += 1

    def _terminate_slot(self, i: int, status: str) -> None:
        req = self.slots[i]
        self._release_slot(i)
        self._terminate_req(req, status)

    def cancel(self, uid: int) -> bool:
        """Abandon request ``uid``, queued or in-flight (satellite fix:
        previously a submitted request held its slot and pages until
        ``max_new_tokens`` completed, no matter what).

        Page references are dropped exactly once (``_release_slot``
        empties the page list) and only the ROW's references — pages the
        prefix tree shares stay cached for other requests.  In-flight
        tokens are harvested first, so ``generated`` holds everything
        the request produced before the cancel.  Returns True if the
        request was live and is now cancelled; False if unknown or
        already terminal (a second cancel is a no-op)."""
        req = self._all.get(uid)
        if req is None or req.done or req.status in TERMINAL_STATES:
            return False
        # flush pending device steps: a record in flight may complete the
        # request (then cancel is too late and reports False), and the
        # bookkeeping below needs ``generated`` final
        self._harvest()
        if req.done:
            return False
        if self.queue.remove(uid) is not None:      # queued or preempted
            self._terminate_req(req, "cancelled")
            return True
        for i, r in enumerate(self.slots):
            if r is not None and r.uid == uid:
                self._terminate_slot(i, "cancelled")
                return True
        return False

    def _sweep_deadlines(self) -> None:
        """Expire every request whose deadline passed (runs at the top of
        each step; ``_now`` is monkeypatchable in tests).  Queued
        requests just leave the queue; in-flight rows release their
        pages through the same exactly-once path as cancel."""
        if not self._has_deadlines:
            return
        now = self._now()
        for r in list(self.queue):
            if r.deadline_s is not None and now >= r.deadline_s:
                if self.queue.remove(r.uid) is not None:
                    self._terminate_req(r, "expired")
        for i, req in enumerate(self.slots):
            if (req is not None and req.deadline_s is not None
                    and now >= req.deadline_s):
                self._harvest()
                if not req.done:
                    self._terminate_slot(i, "expired")

    def _now(self) -> float:
        return time.perf_counter()

    def _quarantine(self, i: int) -> bool:
        """Row ``i``'s logits came back non-finite (NaN guard): withhold
        every host-state advance so the row re-dispatches from its last
        durable cache state next iteration (the repeated block write is
        idempotent; in-jit, ``last_tok`` was already shielded).  After
        ``nan_retry_limit`` consecutive bad steps the row is failed and
        its pages released instead of spinning forever.  Returns True
        when the row was failed (the caller's harvest boundary)."""
        req = self.slots[i]
        self.stats["nan_quarantines"] += 1
        req.nan_retries += 1
        if req.nan_retries > self.ecfg.nan_retry_limit:
            self._terminate_slot(i, "failed")
            return True
        return False

    def _quarantine_check(self, bad) -> np.ndarray:
        """THE quarantine sync point (``EngineConfig.nan_guard``): fetch
        the step's per-row finiteness verdict.  Deliberately a blocking
        device->host read in the hot loop — the guard trades the async
        pipeline's run-ahead for per-step integrity, the same opt-in
        trade as ``async_steps=False`` — so it lives OUTSIDE the R4
        host-sync scan's hot-method set as a documented boundary, like
        ``_harvest``."""
        return np.asarray(jax.device_get(bad))

    def resilience_stats(self) -> dict:
        """Scheduler + fault-guard counters for reporting (launch/serve,
        benchmarks/serving_engine, the chaos harness)."""
        s = self.stats
        out = {k: s[k] for k in
               ("preemptions", "restores", "restore_hit_tokens",
                "cancelled", "expired", "failed", "alloc_stalls",
                "dispatch_failures", "nan_quarantines", "active_hwm")}
        out["preempt_log_len"] = len(self.preempt_log)
        return out

    def paged_stats(self) -> dict:
        """Page-pool / prefix-cache counters for reporting (launch/serve,
        benchmarks).  ``{"paged": False}`` on the contiguous cache."""
        if not self.paged:
            return {"paged": False}
        s = self.stats
        return {
            "paged": True,
            "paged_kernel": self.ecfg.paged_kernel,
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "pages_in_use": self.allocator.pages_in_use,
            "pages_hwm": s["pages_hwm"],
            "pool_utilization": s["pages_hwm"] / self.num_pages,
            "prefix_lookups": s["prefix_lookups"],
            "prefix_hits": s["prefix_hits"],
            "prefix_hit_rate": s["prefix_hits"] / max(s["prefix_lookups"], 1),
            "prefix_hit_tokens": s["prefix_hit_tokens"],
            "prefix_cached_pages": self.prefix.cached_pages,
            "prefix_evictions": self.prefix.evictions,
            "cow_copies": s["cow_copies"],
        }

    def memory_stats(self) -> dict:
        """Device-memory report (satellite of docs/DESIGN.md §8): total
        GLOBAL weight bytes of the params pytree (QuantTensor leaves
        count their int8/int4 payload + fp32 scales — the number the
        quantized store shrinks), KV pool bytes (contiguous slots or page
        pool), and their sum.  On a single node this IS the per-node
        budget ``perf_model.fits_in_memory`` checks; on an expert-parallel
        mesh the arrays here are global (each node holds only its expert
        shard plus the replicated rest — ``perf_model.
        per_node_weight_bytes`` models that split)."""
        weight = quant.tree_bytes(self.params)
        pool = quant.tree_bytes(self.cache)
        return {
            "weight_bytes": weight,
            "kv_pool_bytes": pool,
            "total_bytes": weight + pool,
            "weight_quant": getattr(self.cfg, "weight_quant", "none"),
        }

    # -- harvest: the only device sync in the loop --------------------------

    def _harvest(self) -> None:
        """Fetch all pending step outputs and apply them to requests/tracker
        in dispatch order.  Each record is fetched with its own timed
        ``device_get`` — computations complete in dispatch order, so the
        per-record wait IS that step's remaining device time, giving an
        honest prefill/decode split of the async pipeline's wall clock."""
        if not self._pending:
            return
        recs, self._pending = self._pending, []
        self.stats["harvests"] += 1
        for rec in recs:
            t0 = time.perf_counter()
            tok, routing = jax.device_get((rec.tok, rec.routing))
            dt = time.perf_counter() - t0
            self.stats["harvest_s"] += dt
            self.stats[{"prefill": "prefill_s", "decode": "decode_s",
                        "mixed": "mixed_s"}[rec.kind]] += dt
            if rec.stalled:
                self.stats["stall_s"] += dt
            now = time.perf_counter()
            for _, slot, req in rec.rows:
                req.generated.append(int(tok[slot]))
                if req.first_token_s is None:
                    req.first_token_s = now
                if len(req.generated) >= req.max_new_tokens:
                    req.done = True
                    if req.status not in TERMINAL_STATES:
                        req.status = "done"
            self._observe_routing(rec, routing)

    def _observe_routing(self, rec: _Pending, routing) -> None:
        """Feed the tracker from the device capture (host does NO routing)."""
        if self.tracker is None or routing is None:
            return
        # prefill/unified: (L, B*S, K) -> (L, B, S*K); decode: (L, B, K)
        per_row = routing.reshape(routing.shape[0], rec.routing_batch, -1)
        row_ids = (list(rec.obs_rows) if rec.obs_rows is not None
                   else [row for row, _, _ in rec.rows])
        for layer in range(self.cfg.num_layers):
            ids = per_row[layer, row_ids]
            # unified blocks dead-route invalid tokens to the E_pad
            # sentinel; those entries are scheduling padding, not executed
            # experts — drop them before they reach the tracker
            self.tracker.observe(layer, ids[ids < self.cfg.num_experts])
        self.tracker.tick()

    def flush(self) -> None:
        """Sync: harvest every dispatched-but-unapplied step."""
        self._harvest()

    def run_until_done(self, max_steps: int = 10_000) -> list[Request]:
        done: list[Request] = []
        seen: set[int] = set()
        pending = lambda: self.queue or any(s is not None for s in self.slots)
        steps = 0
        while pending() and steps < max_steps:
            self.step()
            steps += 1
            for r in self._all.values():
                if r.done and r.uid not in seen:
                    seen.add(r.uid)
                    done.append(r)
        self.flush()
        for r in self._all.values():
            if r.done and r.uid not in seen:
                seen.add(r.uid)
                done.append(r)
        return done

    # -- paper policy artifacts ---------------------------------------------

    def standby(self) -> Array:
        """The paper's between-request keep-warm: a summing touch over every
        expert weight (§4.2 'standby calculation')."""
        if not self.cfg.is_moe:
            return jnp.zeros(())
        ex = self.params["blocks"]["experts"]
        return sum(jnp.sum(w.astype(jnp.float32)) for w in jax.tree.leaves(ex))

    def expected_experts_per_node(self, n_nodes: int) -> float:
        """Measured Table-1 statistic from the tracker (exact: computed from
        the device-captured routing decisions of every served step)."""
        if self.tracker is None:
            return float("nan")
        self.flush()
        return self.tracker.mean_executed_per_node(n_nodes)

    def throughput(self) -> dict:
        """Per-phase tok/s.  ``prefill_s``/``decode_s``/``mixed_s`` hold
        dispatch time plus each phase's harvest wait (see _harvest), so the
        split is meaningful in async mode too; ``total`` is the combined
        rate over all three buckets (unified iterations that mix prefill
        chunks with decode rows land in ``mixed_s``).

        Mixed-iteration time is APPORTIONED between the two per-phase
        denominators by each phase's token share of those iterations
        (``mixed_prefill_tokens`` / ``mixed_decode_tokens``) — the
        satellite fix: charging all of ``mixed_s`` to *both* phases
        systematically deflated both rates (their reciprocals summed to
        more than the measured wall time).

        ``prefill_tokens`` counts REAL prompt tokens only;
        ``prefill_padding_overhead`` is the fraction of prefill positions
        the reference path spent recomputing padding (0 in unified mode —
        the satellite fix for the seed's inflated prefill tok/s).
        ``decode_stall_s`` is reference-mode device time during which
        in-flight decode rows sat idle behind a separate prefill program
        (0 by construction in unified mode)."""
        s = self.stats
        work_s = s["prefill_s"] + s["decode_s"] + s["mixed_s"]
        pad = s["prefill_pad_tokens"]
        mp, md = s["mixed_prefill_tokens"], s["mixed_decode_tokens"]
        p_share = mp / (mp + md) if (mp + md) else 0.0
        prefill_den = s["prefill_s"] + s["mixed_s"] * p_share
        decode_den = s["decode_s"] + s["mixed_s"] * (1.0 - p_share)
        return {
            "prefill_tok_per_s": s["prefill_tokens"] / max(prefill_den,
                                                           1e-9),
            "decode_tok_per_s": s["decode_tokens"] / max(decode_den, 1e-9),
            "total_tok_per_s": (s["prefill_tokens"] + s["decode_tokens"])
                               / max(work_s, 1e-9),
            "prefill_padding_overhead": pad / max(pad + s["prefill_tokens"],
                                                  1),
            "decode_stall_s": s["stall_s"],
        }

    def ttft(self, since: float = 0.0) -> dict:
        """Time-to-first-token stats over completed requests (seconds,
        harvest-boundary resolution — honest for sync stepping; async mode
        coalesces harvests, so pair with ``async_steps=False`` when TTFT is
        the metric under study).  ``since`` drops requests submitted before
        that ``time.perf_counter()`` stamp (e.g. compile-time warmups)."""
        ts = sorted(r.first_token_s - r.submit_s for r in self._all.values()
                    if r.first_token_s is not None and r.submit_s >= since)
        if not ts:
            return {"n": 0, "p50": float("nan"), "p95": float("nan")}
        pct = lambda p: ts[min(int(p * (len(ts) - 1) + 0.5), len(ts) - 1)]
        return {"n": len(ts), "p50": pct(0.50), "p95": pct(0.95),
                "mean": sum(ts) / len(ts)}

"""Deterministic fault injection for the serving engine (docs/DESIGN.md §10).

The paper's target is an always-on private serving cluster: the engine
must survive overload and partial failure, not assume a benign batch.
This module is the *controlled adversary* half of that story — a
seedable, replayable schedule of faults the engine's guards are gated
against (tests/test_resilience.py, ``python -m repro.serving.chaos``,
the CI ``chaos-smoke`` job).

A :class:`FaultPlan` maps ``(step, site)`` to a :class:`Fault`, where
``step`` is the engine's iteration counter (``ServingEngine`` increments
it once per ``step()`` call, first call = 1) and ``site`` is one of:

  * ``"alloc"``    — the page allocator reports exhaustion for that
    iteration: admission and lazy decode-page growth both see zero free
    pages (no eviction, no preemption is attempted — the fault models a
    pool with nothing reclaimable).  Guarded by: the starved row/request
    simply does not advance that iteration and is retried on the next
    (``stats["alloc_stalls"]``); refcounts are never touched.
  * ``"dispatch"`` — the jit dispatch raises :class:`InjectedFault`
    *instead of* running (a backend refusing the launch).  Guarded by:
    the engine catches it before any host bookkeeping was mutated, so
    the identical iteration is re-dispatched next ``step()``
    (``stats["dispatch_failures"]``).  The injection fires before the
    donated cache operand is consumed, so the buffer stays valid.
  * ``"nan"``      — the chosen rows' logits are overwritten with
    NaN (or +inf, ``kind="inf"``) *inside* the jit via a runtime poison
    vector (no retrace).  Guarded by: the jit always returns a per-row
    ``bad = ~all(isfinite(logits))`` flag; with the quarantine guard on
    (``EngineConfig.nan_guard``, auto-enabled when a plan is installed)
    the engine fetches it, withholds the poisoned rows' host-state
    advance (lengths / prefill_pos / budgets / token record), and
    re-dispatches them from their last durable cache state — the
    repeated block writes are idempotent, neighbours never see the
    fault, and a row that stays non-finite for
    ``EngineConfig.nan_retry_limit`` consecutive steps is cancelled
    with status ``"failed"`` instead of spinning forever.

Determinism: a plan is a pure value — the same plan against the same
engine/workload fires the same faults at the same iterations, which is
what lets the chaos gates demand *token-identical* output on every
unfaulted (and, for transient faults, every faulted-then-recovered)
request.  ``FaultPlan.random(seed, ...)`` derives a schedule from a
``numpy`` generator so randomized chaos runs are replayable from the
seed alone.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

SITES = ("alloc", "dispatch", "nan")


class InjectedFault(RuntimeError):
    """Raised at a ``dispatch`` fault site; carries the fault record."""

    def __init__(self, fault: "Fault"):
        super().__init__(f"injected fault {fault.site!r} at engine step "
                         f"{fault.step}")
        self.fault = fault


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected fault: fire at engine iteration ``step`` on ``site``.

    ``rows`` selects which batch rows a ``"nan"`` fault poisons (empty =
    every row); ``kind`` picks the poison value (``"nan"`` or ``"inf"``).
    Both are ignored by the other sites."""
    step: int
    site: str
    rows: tuple = ()
    kind: str = "nan"

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"pick from {SITES}")
        if self.kind not in ("nan", "inf"):
            raise ValueError(f"unknown poison kind {self.kind!r}")
        if self.step < 1:
            raise ValueError(f"fault step must be >= 1, got {self.step}")

    @property
    def value(self) -> float:
        return float("inf") if self.kind == "inf" else float("nan")


class FaultPlan:
    """An immutable schedule of faults keyed on ``(step, site)``.

    The engine ``poll()``s each site it guards once per iteration; a
    poll that matches records the fault in ``fired`` (once per key), so
    harnesses can assert the plan was actually exercised
    (``all_fired()``) — a chaos gate that silently injected nothing
    would prove nothing."""

    def __init__(self, faults: Iterable[Fault] = ()):
        self._by_key: dict[tuple[int, str], Fault] = {}
        for f in faults:
            key = (f.step, f.site)
            if key in self._by_key:
                raise ValueError(f"duplicate fault at {key}")
            self._by_key[key] = f
        self.fired: list[Fault] = []
        self._fired_keys: set[tuple[int, str]] = set()

    def __len__(self) -> int:
        return len(self._by_key)

    def __iter__(self):
        return iter(sorted(self._by_key.values(),
                           key=lambda f: (f.step, f.site)))

    def poll(self, step: int, site: str) -> Fault | None:
        """The engine's query point: the fault active at (step, site),
        or None.  Each fault fires exactly ONCE — repeat polls of the
        same key return None, so a retry that re-polls within the same
        step sees the fault cleared (transient-failure semantics)."""
        f = self._by_key.get((step, site))
        if f is None or (step, site) in self._fired_keys:
            return None
        self._fired_keys.add((step, site))
        self.fired.append(f)
        return f

    def maybe_raise(self, step: int, site: str) -> None:
        """Raise :class:`InjectedFault` if a fault is active — the
        ``dispatch`` site's idiom (the engine catches it in place of the
        real backend error)."""
        f = self.poll(step, site)
        if f is not None:
            raise InjectedFault(f)

    def all_fired(self) -> bool:
        return len(self.fired) == len(self._by_key)

    def unfired(self) -> list[Fault]:
        return [f for k, f in sorted(self._by_key.items())
                if k not in self._fired_keys]

    @classmethod
    def random(cls, seed: int, *, n_faults: int, max_step: int,
               sites: tuple = SITES, max_batch: int = 1,
               min_step: int = 1) -> "FaultPlan":
        """A replayable randomized schedule: ``n_faults`` faults at
        distinct (step, site) keys drawn from ``[min_step, max_step]`` ×
        ``sites``; NaN faults poison one random row of ``max_batch``."""
        rng = np.random.default_rng(seed)
        keys: set[tuple[int, str]] = set()
        faults: list[Fault] = []
        tries = 0
        while len(faults) < n_faults and tries < 100 * n_faults:
            tries += 1
            step = int(rng.integers(min_step, max_step + 1))
            site = str(rng.choice(sites))
            if (step, site) in keys:
                continue
            keys.add((step, site))
            if site == "nan":
                faults.append(Fault(step, site,
                                    rows=(int(rng.integers(0, max_batch)),),
                                    kind=str(rng.choice(["nan", "inf"]))))
            else:
                faults.append(Fault(step, site))
        return cls(faults)

"""Deterministic chaos smoke: the fault matrix the CI gate drives.

Runs the unified, paged, and paged-kernel (Pallas block-table attention,
PR 8) engines through every fault site (serving/faults.py) plus
overcommit-preemption scenarios, and gates the resilience contract end
to end:

  1. no crash — every injected fault is absorbed by an engine guard
     (alloc exhaustion stalls admission, a failed dispatch re-runs the
     identical iteration, non-finite logits quarantine the row);
  2. token identity — greedy decoding under transient faults emits the
     EXACT token stream of the fault-free baseline (retries re-dispatch
     the same program over the same state, so recovery is invisible);
  3. allocator hygiene — after the workload drains and the prefix tree
     is cleared, every page is back on the free list and refcounts are
     internally consistent (``PageAllocator.check_consistent``);
  4. coverage — every fault in the plan actually fired
     (``FaultPlan.all_fired``), so a scheduling change cannot silently
     skip a site and rot the matrix;
  5. trace budget — fault recovery adds ZERO jit traces beyond the
     documented steady-state set (analysis R3 budgets).

The matrix is seeded and host-driven, so a failure replays exactly:

    PYTHONPATH=src python -m repro.serving.chaos [--arch ...]

Exit status 0 on a clean matrix, 1 with a per-scenario report otherwise.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.analysis.retrace import expected_trace_budget
from repro.configs.base import get_config
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.faults import Fault, FaultPlan

# (name, layout, fault site) — alloc faults need the page allocator;
# "kernel" is the paged layout attended through the Pallas block-table
# kernel (EngineConfig.paged_kernel, PR 8) — same fault sites, and its
# fault-free baseline must equal the gather layout's token-for-token
SCENARIOS = (
    ("unified/dispatch", "unified", "dispatch"),
    ("unified/nan", "unified", "nan"),
    ("paged/alloc", "paged", "alloc"),
    ("paged/dispatch", "paged", "dispatch"),
    ("paged/nan", "paged", "nan"),
    ("kernel/alloc", "kernel", "alloc"),
    ("kernel/dispatch", "kernel", "dispatch"),
    ("kernel/nan", "kernel", "nan"),
)


def _cfg(arch: str):
    # capacity_factor high enough that token routing never drops tokens:
    # the matrix gates exact token equality across schedules, and capacity
    # drops are schedule-dependent (same reasoning as the serving tests)
    return get_config(arch).reduced().replace(capacity_factor=8.0)


def _engine(cfg, *, layout: str, plan: FaultPlan | None = None,
            num_pages: int = 0, overcommit: bool = False) -> ServingEngine:
    return ServingEngine(cfg, EngineConfig(
        max_batch=2, prefill_len=8, max_cache=32, unified_step=True,
        chunk_len=3, async_steps=False, paged=layout != "unified",
        page_size=4, num_pages=num_pages, overcommit=overcommit,
        paged_kernel=layout == "kernel"), fault_plan=plan)


def _serve(eng: ServingEngine, prompts, new_tokens: int,
           priorities=None) -> dict:
    uids = [eng.submit(p, max_new_tokens=new_tokens,
                       priority=0 if priorities is None else priorities[i])
            for i, p in enumerate(prompts)]
    eng.run_until_done()
    return {i: list(eng._all[u].generated) for i, u in enumerate(uids)}


def _check_drained(eng: ServingEngine, errors: list, name: str) -> None:
    for r in eng._all.values():
        if r.status != "done":
            errors.append(f"{name}: request {r.uid} ended {r.status!r}")
    if eng.paged:
        eng.prefix.clear()
        if not eng.allocator.fully_free:
            errors.append(f"{name}: {eng.allocator.num_pages - eng.allocator.free_pages} pages leaked after drain")
        try:
            eng.allocator.check_consistent()
        except AssertionError as e:
            errors.append(f"{name}: allocator inconsistent — {e}")


def _check_traces(eng: ServingEngine, errors: list, name: str) -> None:
    budget = expected_trace_budget(eng)
    for key, count in sorted(eng.trace_counts.items()):
        if count > budget.get(key, 0):
            errors.append(f"{name}: jit body '{key}' traced {count}x "
                          f"(budget {budget.get(key, 0)}) — fault recovery "
                          "must reuse the steady-state programs")


def run_matrix(arch: str, *, new_tokens: int = 6, seed: int = 0,
               verbose: bool = True) -> list:
    cfg = _cfg(arch)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, 7),
               rng.integers(0, cfg.vocab_size, 5)]
    errors: list = []

    # fault-free baselines, one per layout
    baseline = {}
    for layout in ("unified", "paged", "kernel"):
        eng = _engine(cfg, layout=layout)
        baseline[layout] = _serve(eng, prompts, new_tokens)
        _check_drained(eng, errors, f"baseline/{layout}")
    # the Pallas kernel is the same attention over the same pool: its
    # fault-free stream must equal the gather layout's before any fault
    # scenario is worth running (PR 8 cross-path gate)
    if baseline["kernel"] != baseline["paged"]:
        errors.append("baseline/kernel: paged-attention kernel diverged "
                      "from the virtual-cache gather, fault-free")
    if errors:        # a broken baseline invalidates the whole matrix
        return errors

    for name, layout, site in SCENARIOS:
        # three injections of the site spread over the run; nan faults
        # poison alternating rows so both slots exercise the quarantine
        if site == "nan":
            faults = [Fault(s, "nan", rows=(i % 2,),
                            kind=("nan", "inf")[i % 2])
                      for i, s in enumerate((2, 4, 7))]
        elif site == "alloc":
            # alloc faults only fire when an allocation attempt polls the
            # site: steps 1 and 2 hit admission + its immediate retry
            faults = [Fault(s, site) for s in (1, 2)]
        else:
            faults = [Fault(s, site) for s in (1, 3, 6)]
        plan = FaultPlan(faults)
        eng = _engine(cfg, layout=layout, plan=plan)
        try:
            got = _serve(eng, prompts, new_tokens)
        except Exception as e:                     # gate 1: no crash
            errors.append(f"{name}: crashed — {type(e).__name__}: {e}")
            continue
        if got != baseline[layout]:                # gate 2: token identity
            errors.append(f"{name}: tokens diverged from fault-free run")
        if not plan.all_fired():                   # gate 4: coverage
            errors.append(f"{name}: unfired faults {plan.unfired()}")
        _check_drained(eng, errors, name)          # gate 3: hygiene
        _check_traces(eng, errors, name)           # gate 5: budget
        if verbose:
            st = {k: v for k, v in eng.resilience_stats().items() if v}
            print(f"  {name:18s} ok={got == baseline[layout]}  {st}")

    # overcommit-preemption: a pool too small for both lifetimes forces a
    # mid-decode preempt + prefix-cache restore; tokens must still match
    # the uncontended GATHER layout — the kernel row additionally proves
    # the Pallas path re-attends correctly through remapped block tables
    big = _engine(cfg, layout="paged")
    want = _serve(big, prompts, 8)
    for layout in ("paged", "kernel"):
        name = f"{layout}/preempt"
        eng = _engine(cfg, layout=layout, num_pages=4, overcommit=True)
        try:
            got = _serve(eng, prompts, 8, priorities=[0, 5])
        except Exception as e:
            errors.append(f"{name}: crashed — {type(e).__name__}: {e}")
            continue
        if got != want:
            errors.append(f"{name}: preempted run diverged from "
                          "uncontended run")
        st = eng.resilience_stats()
        if st["preemptions"] < 1 or st["restores"] < 1:
            errors.append(f"{name}: pool pressure produced no "
                          f"preempt/restore cycle ({st})")
        _check_drained(eng, errors, name)
        _check_traces(eng, errors, name)
        if verbose:
            print(f"  {name:18s} ok={got == want}  "
                  f"{{'preemptions': {st['preemptions']}, "
                  f"'restores': {st['restores']}}}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_moe_30b_a3b")
    ap.add_argument("--new-tokens", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    print(f"chaos matrix: {args.arch} (seed {args.seed})")
    errors = run_matrix(args.arch, new_tokens=args.new_tokens,
                        seed=args.seed)
    if errors:
        print(f"\nFAIL — {len(errors)} gate violation(s):")
        for e in errors:
            print(f"  - {e}")
        return 1
    print("chaos matrix clean: no crashes, token-identical recovery, "
          "allocator fully free, all faults fired, zero extra traces")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Checkpointing: flat-key npz save/restore + the prestacking converter.

The converter is the TPU analogue of the paper's one-time preprocessing
script (§4.1): it takes an *unstacked* checkpoint (one entry per layer /
per expert, the naive layout) and rewrites it into the canonical
*prestacked* layout — one contiguous array per weight kind with leading
(L[, E]) axes — including granite-style expert padding.  With
``weight_quant`` it ALSO quantizes eligible weight kinds into the
blockwise QuantTensor store (docs/DESIGN.md §8) in the same one-time
pass, so serving restores ready-to-run compressed weights.

QuantTensor leaves round-trip through the flat npz format as three sibling
entries (``<key>//__qt_data__``, ``//__qt_scale__``, ``//__qt_meta__``) —
payload, scales, and the static quantization metadata.
"""
from __future__ import annotations

import os
import re

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import prestack, quant

SEP = "//"

_QT_DATA, _QT_SCALE, _QT_META = "__qt_data__", "__qt_scale__", "__qt_meta__"
# dtypes a QuantTensor may dequantize to, indexed by the meta record
_QT_DTYPES = ("float32", "bfloat16", "float16", "float64")


def flatten_tree(tree) -> dict:
    flat = {}

    def rec(t, path):
        if isinstance(t, quant.QuantTensor):
            flat[SEP.join(path + [_QT_DATA])] = t.data
            flat[SEP.join(path + [_QT_SCALE])] = t.scale
            flat[SEP.join(path + [_QT_META])] = np.asarray(
                [t.bits, t.block, t.orig_dim,
                 _QT_DTYPES.index(t.out_dtype)], np.int64)
        elif isinstance(t, dict):
            for k in sorted(t):
                rec(t[k], path + [str(k)])
        else:
            flat[SEP.join(path)] = t

    rec(tree, [])
    return flat


def unflatten_tree(flat: dict) -> dict:
    tree: dict = {}
    for key, v in flat.items():
        node = tree
        parts = key.split(SEP)
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        if _QT_DATA in node:
            meta = np.asarray(node[_QT_META])
            return quant.QuantTensor(
                jnp.asarray(node[_QT_DATA]), jnp.asarray(node[_QT_SCALE]),
                int(meta[0]), int(meta[1]), int(meta[2]),
                _QT_DTYPES[int(meta[3])])
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(tree)


def save(path: str, params, step: int = 0) -> None:
    flat = {k: np.asarray(v) for k, v in flatten_tree(params).items()}
    flat["__step__"] = np.asarray(step, np.int64)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **flat)


def restore(path: str) -> tuple[dict, int]:
    with np.load(path if path.endswith(".npz") else path + ".npz") as z:
        flat = {k: z[k] for k in z.files if k != "__step__"}
        step = int(z["__step__"]) if "__step__" in z.files else 0
    flat = {k: (v if k.endswith(_QT_META) else jnp.asarray(v))
            for k, v in flat.items()}
    return unflatten_tree(flat), step


# ---------------------------------------------------------------------------
# prestack converter (paper §4.1, the one-time stacking script)
# ---------------------------------------------------------------------------

_LAYER_RE = re.compile(r"^layer_(\d+)$")
_EXPERT_RE = re.compile(r"^expert_(\d+)$")


def convert_unstacked(unstacked: dict, num_experts_padded: int = 0,
                      weight_quant: str = "none",
                      weight_quant_block: int = 128,
                      weight_quant_kinds: tuple = quant.DEFAULT_KINDS) -> dict:
    """{"layer_0": {...}, "layer_1": {...}} -> prestacked tree with a leading
    L axis; inside each layer an optional {"expert_<i>": {...}} level is
    stacked into a leading E axis and zero-padded to ``num_experts_padded``.

    ``weight_quant`` extends the one-time preprocessing with the blockwise
    weight store (docs/DESIGN.md §8): after stacking, eligible weight
    kinds are quantized into QuantTensor leaves — the quantize-on-load
    pipeline shares one pass with the paper's prestacking script.
    """
    layer_keys = sorted((k for k in unstacked if _LAYER_RE.match(k)),
                        key=lambda k: int(_LAYER_RE.match(k).group(1)))
    if not layer_keys:
        raise ValueError("no layer_<i> entries found")

    def stack_layer(layer: dict) -> dict:
        e_keys = sorted((k for k in layer if _EXPERT_RE.match(k)),
                        key=lambda k: int(_EXPERT_RE.match(k).group(1)))
        if not e_keys:
            return layer
        experts = prestack.stack_experts([layer[k] for k in e_keys])
        if num_experts_padded:
            experts = prestack.pad_experts(experts, num_experts_padded)
        rest = {k: v for k, v in layer.items() if k not in e_keys}
        return {**rest, "experts": experts}

    blocks = prestack.stack_blocks([stack_layer(unstacked[k])
                                    for k in layer_keys])
    return prestack.quantize_blocks(blocks, weight_quant,
                                    block=weight_quant_block,
                                    kinds=weight_quant_kinds)


def to_unstacked(blocks, num_layers: int) -> dict:
    """Inverse converter (prestacked -> naive layout) for the Fig.4-style
    baseline benchmark."""
    return {f"layer_{i}": layer
            for i, layer in enumerate(prestack.unstack_blocks(blocks))}


def quantize_on_load(path: str, cfg) -> tuple[dict, int]:
    """Restore a checkpoint and apply ``cfg.weight_quant`` — the serving
    loader's one-time preprocessing (idempotent: checkpoints saved already
    quantized restore as QuantTensor leaves and pass through)."""
    params, step = restore(path)
    return quant.quantize_params(params, cfg), step

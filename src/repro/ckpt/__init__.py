from repro.ckpt.io import save, restore, convert_unstacked, to_unstacked, flatten_tree, unflatten_tree

"""Qwen3-30B-A3B MoE [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4, head_dim=128) per-expert d_ff=768,
vocab=151936, 128 experts top-8.  Primary target of the paper's
expert-parallel technique (docs/DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936,
    num_experts=128, num_experts_padded=128, experts_per_token=8,
    qk_norm=True, rope_theta=1e6,
    source="hf:Qwen/Qwen3-30B-A3B",
)

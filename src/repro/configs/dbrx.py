"""DBRX-Instruct 132B — the paper's own model [Table 1 / databricks blog].

40L d_model=6144 48H (GQA kv=8, head_dim=128) per-expert d_ff=10752,
16 experts top-4, vocab ~100k (tiktoken).  Used by the reproduction
benchmarks (Tables 3/4/6) and the perf model.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352,
    num_experts=16, num_experts_padded=16, experts_per_token=4,
    norm="layernorm", rope_theta=5e5,
    source="DOI:10.1145/3649601.3698722 Table 1",
)

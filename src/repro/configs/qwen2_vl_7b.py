"""Qwen2-VL-7B language backbone [arXiv:2409.12191].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064, M-RoPE.
ViT frontend stubbed: input_specs() provides patch embeddings prepended to
the text tokens, with 3D M-RoPE positions for the full sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    mrope=True, mrope_sections=(16, 24, 24), qkv_bias=True, rope_theta=1e6,
    num_patch_tokens=1024,
    source="arXiv:2409.12191",
)

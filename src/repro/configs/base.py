"""Config system: ModelConfig (architecture + runtime knobs), the four
assigned input shapes, and ``input_specs()`` ShapeDtypeStruct stand-ins.

Every assigned architecture provides a module ``configs/<id>.py`` exporting
``CONFIG`` (exact published spec) built from this dataclass; ``reduced()``
derives the CPU smoke-test variant (<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Any

import jax
import jax.numpy as jnp


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int
    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    qk_norm: bool = False
    qkv_bias: bool = False
    use_rope: bool = True
    rope_theta: float = 1e6
    mrope: bool = False
    mrope_sections: tuple = (16, 24, 24)
    sliding_window: int | None = None     # native local attention (hybrid)
    long_context_window: int = 4096       # SWA variant for long_500k
    long_context_threshold: int = 262144  # >= this seq len -> use SWA variant
    # moe
    num_experts: int = 0
    num_experts_padded: int = 0      # >= num_experts, divisible by EP shards
    experts_per_token: int = 0
    router_norm_topk: bool = True
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_dconv: int = 4
    # hybrid (rg-lru)
    lru_width: int = 0
    conv1d_width: int = 4
    # frontend stubs
    num_patch_tokens: int = 0        # vlm: patch embeddings prepended
    # misc architecture
    act: str = "silu"
    norm: str = "rmsnorm"
    positional: str = "rope"         # rope | sinusoidal | none
    tie_embeddings: bool = False
    # runtime / paper-method knobs (docs/DESIGN.md §5)
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    moe_strategy: str = "dispatch"          # dense (=L_B) | dispatch (=L_R)
    # expert-parallel collective schedule (docs/DESIGN.md §5):
    #   centralized | decentralized | a2a | a2a_pipelined
    # a2a_pipelined splits the local token block into ``ep_microchunks``
    # chunks and software-pipelines them so chunk i's expert FFN overlaps
    # chunk i+1's all_to_all dispatch (double-buffered scan); token-exact
    # vs a2a whenever capacity is not binding, and falls back to a2a /
    # decentralized exactly where a2a would.
    expert_parallel: str = "decentralized"
    expert_replication: int = 1             # paper §5.3 overlapping placement
    capacity_factor: float = 1.25
    # number of microchunks for the a2a_pipelined schedule (1 = no
    # pipelining; values that do not divide the local token count fall back
    # to plain a2a)
    ep_microchunks: int = 1
    # capacity-free decode fast path: when a dispatch-strategy MoE layer
    # sees T*K routing decisions at or below this threshold (small decode
    # batches), it skips the fixed-capacity dispatch — whose round_capacity
    # floor of 8 slots/expert makes tiny batches compute mostly padding —
    # whenever a capacity-free form is cheaper: a reference_moe-style
    # per-token gather (core/moe.gather_moe; reads only the selected
    # experts' weights) when T*K <= E_local, or the one-hot dense compute
    # when T is below the capacity floor.  Those forms never drop tokens;
    # outside both cut-offs the normal dispatch (capacity semantics,
    # possible drops) still runs.  0 disables the fast path.
    gather_decode_max_tk: int = 64
    prestack: bool = True                   # C2: stacked layer/expert layout
    use_kernel: bool = False                # Pallas grouped-GEMM path
    use_flash_kernel: bool = False          # Pallas flash-attention path
    remat: bool = True
    vocab_pad: int = 256
    kv_cache_shard: str = "seq"             # seq (CP decode) | hd | kv | none
    kv_cache_dtype: str = "native"          # native | int8 (quantized cache)
    # blockwise quantized weight store (core/quant.py, docs/DESIGN.md §8):
    # none | int8 | int4.  Weights of the kinds listed in
    # ``weight_quant_kinds`` become QuantTensor pytree leaves (int8 or
    # packed-int4 payload + per-``weight_quant_block`` fp32 scales over the
    # reduction axis) at load time (ckpt/io.py, serving/engine.py); every
    # matmul site goes through core/quant.qdot, so raw and quantized
    # params are interchangeable.  The router and embedding stay fp by
    # default (the per-kind override: shrink what dominates memory, keep
    # the precision-sensitive tiny matrices exact).
    weight_quant: str = "none"
    weight_quant_block: int = 128
    weight_quant_kinds: tuple = ("attn", "mlp", "experts", "lm_head")
    source: str = ""                 # citation

    # -- derived ----------------------------------------------------------
    @property
    def dtype_jnp(self):
        return jnp.dtype(self.dtype)

    @property
    def param_dtype_jnp(self):
        return jnp.dtype(self.param_dtype)

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def n_params(self) -> int:
        """Total parameter count (ignores vocab/expert padding)."""
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di = self.ssm_expand * d
            h = di // self.ssm_headdim
            per = (d * (2 * di + 2 * self.ssm_state + h)
                   + self.ssm_dconv * (di + 2 * self.ssm_state)
                   + di * d + 3 * h + di)
            return emb + L * per
        attn = d * (self.num_heads + 2 * self.num_kv_heads) * self.head_dim \
            + self.num_heads * self.head_dim * d
        if self.is_moe:
            ffn = self.num_experts * 3 * d * self.d_ff + d * self.num_experts
        else:
            ffn = 3 * d * self.d_ff
        if self.family == "hybrid":
            n_attn = sum(1 for i in range(L) if i % 3 == 2)
            n_rec = L - n_attn
            w = self.lru_width
            rec = d * w * 2 + self.conv1d_width * w + 2 * w * w + w * d + 3 * w
            return emb + n_attn * (attn + ffn) + n_rec * (rec + ffn)
        return emb + L * (attn + ffn)

    def n_active_params(self) -> int:
        """Active per-token params (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.n_params()
        d, L = self.d_model, self.num_layers
        attn = d * (self.num_heads + 2 * self.num_kv_heads) * self.head_dim \
            + self.num_heads * self.head_dim * d
        ffn = self.experts_per_token * 3 * d * self.d_ff + d * self.num_experts
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return emb + L * (attn + ffn)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts, same family."""
        d = min(self.d_model, 256)
        hd = 64
        heads = max(2, min(4, self.num_heads))
        kv = 1 if self.num_kv_heads == 1 else 2
        kw: dict[str, Any] = dict(
            name=self.name + "-reduced",
            num_layers=3 if self.family == "hybrid" else 2,
            d_model=d, vocab_size=min(self.vocab_size, 512),
            dtype="float32", param_dtype="float32", remat=False,
        )
        if self.family != "ssm":
            kw.update(num_heads=heads, num_kv_heads=kv, head_dim=hd,
                      d_ff=min(self.d_ff, 512) if self.d_ff else 0)
        if self.is_moe:
            kw.update(num_experts=4, num_experts_padded=4, experts_per_token=2)
        if self.family == "ssm":
            kw.update(ssm_state=16, ssm_headdim=32)
        if self.family == "hybrid":
            kw.update(lru_width=d, sliding_window=64)
        if self.mrope:
            kw.update(num_patch_tokens=8, head_dim=128, num_heads=2)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode | mixed
    seq_len: int         # mixed: the cache/context length
    global_batch: int
    # mixed (unified token-budget step, serving/engine.py unified_step):
    # width of the (B, chunk_len) token block each iteration packs with
    # per-row cache offsets — prefill chunks and decode rows share it
    chunk_len: int = 0


def mixed_shape(name: str, cache_len: int, batch: int,
                chunk_len: int) -> ShapeSpec:
    """ShapeSpec for the unified mixed prefill/decode step
    (``Model.forward_routed``): a (batch, chunk_len) token block against a
    ``cache_len`` cache."""
    return ShapeSpec(name, "mixed", cache_len, batch, chunk_len=chunk_len)


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def _sds(shape, dtype, sharding=None):
    if sharding is not None:
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this workload.

    For ``decode`` kinds this covers the *step* inputs only; the cache spec
    comes from ``repro.models.model.cache_specs`` (launch/dryrun.py combines
    the two).  Frontend stubs (audio frames / vision patches) appear here as
    precomputed embeddings — the one sanctioned stub.
    """
    b, s = shape.global_batch, shape.seq_len
    dt = cfg.dtype_jnp
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            specs = {"frame_embeds": _sds((b, s, cfg.d_model), dt),
                     "labels": _sds((b, s), jnp.int32)}
        elif cfg.family == "vlm":
            p = cfg.num_patch_tokens
            specs = {"tokens": _sds((b, s - p), jnp.int32),
                     "patch_embeds": _sds((b, p, cfg.d_model), dt),
                     "mrope_positions": _sds((b, s, 3), jnp.int32),
                     "labels": _sds((b, s), jnp.int32)}
        else:
            specs = {"tokens": _sds((b, s), jnp.int32),
                     "labels": _sds((b, s), jnp.int32)}
        if shape.kind == "prefill":
            specs.pop("labels")
        return specs
    if shape.kind == "mixed":
        # unified token-budget step (Model.forward_routed): a (B, chunk)
        # token block at per-row cache offsets — chunked prefill, decode
        # and mixed batches share these inputs
        c = max(shape.chunk_len, 1)
        return {"tokens": _sds((b, c), jnp.int32),
                "lengths": _sds((b,), jnp.int32),
                "seg_lens": _sds((b,), jnp.int32)}
    # decode: one new token against a cache of seq_len
    specs = {"tokens": _sds((b, 1), jnp.int32),
             "lengths": _sds((b,), jnp.int32)}
    if cfg.family == "vlm":
        specs["mrope_positions"] = _sds((b, 1, 3), jnp.int32)
    return specs


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "musicgen_large", "qwen3_moe_30b_a3b", "granite_moe_3b_a800m",
    "deepseek_67b", "qwen2_vl_7b", "qwen3_0_6b", "stablelm_12b",
    "qwen2_72b", "mamba2_130m", "recurrentgemma_2b",
]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIAS.update({"dbrx": "dbrx", "dbrx-132b": "dbrx"})


def get_config(arch: str) -> ModelConfig:
    arch_mod = _ALIAS.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_mod}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}

"""RecurrentGemma-2B hybrid [arXiv:2402.19427].

26L d_model=2560 10H (GQA kv=1, head_dim=256) d_ff=7680 vocab=256000.
RG-LRU (lru_width=2560) + local attention (window 2048), pattern rec,rec,attn.
long_500k native (bounded attention window + O(1) recurrent state).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    lru_width=2560, conv1d_width=4, sliding_window=2048,
    act="gelu", rope_theta=1e4, tie_embeddings=True,
    source="arXiv:2402.19427",
)

"""Mamba2-130M SSD [arXiv:2405.21060].

24L d_model=768 attention-free, ssm_state=128, headdim=64, expand=2,
vocab=50280.  Expert parallelism inapplicable (docs/DESIGN.md §4); runs under
data(+pod) parallelism; long_500k native via O(1) recurrent state.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_dconv=4,
    tie_embeddings=True, use_rope=False, positional="none",
    source="arXiv:2405.21060",
)

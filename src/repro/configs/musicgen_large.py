"""MusicGen-Large decoder backbone over EnCodec tokens [arXiv:2306.05284].

48L d_model=2048 32H (kv=32 => MHA) d_ff=8192 vocab=2048.  The EnCodec
conv/codec frontend is stubbed: input_specs() provides precomputed frame
embeddings (B, S, d_model); the decoder predicts codec tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048,
    act="gelu", norm="layernorm", positional="sinusoidal", use_rope=False,
    source="arXiv:2306.05284",
)

"""Granite-3.0 3B-A800M MoE [hf:ibm-granite/granite-3.0-1b-a400m-base family].

32L d_model=1536 24H (GQA kv=8) per-expert d_ff=512 vocab=49155,
40 experts top-8.  40 % 16 != 0 -> experts padded to 48 with router-dead
entries (docs/DESIGN.md §4); vocab padded 49155 -> 49408 for sharding.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    num_experts=40, num_experts_padded=48, experts_per_token=8,
    tie_embeddings=True, rope_theta=1e4,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

"""Router-aided dynamic loading (paper §4.2, L_R) — host-side half.

On Apple silicon the LRU top-up keeps idle experts "wired"; on TPU nothing
unwires, so the *device-side* half of L_R is the fixed-capacity dispatch in
core/moe.py.  This module keeps the faithful host-side policy:

  * ``LRUExpertTracker`` — per-layer last-used step per expert, the paper's
    LRU structure.  The serving engine uses it to (a) reproduce the paper's
    E[#executed experts/node/layer] statistic for the perf model, and
    (b) pick refresh candidates for the standby-calculation analogue
    (cross-step expert priming / cache-warming statistics).
  * ``quota_topup`` — given the per-node selected-expert sets of one layer,
    equalize every node's load to the global max by adding LRU experts —
    the exact L_R algorithm (Fig. 6b), reused by benchmarks/table3 to
    emulate the paper's node behaviour.
"""
from __future__ import annotations

import collections

import numpy as np


class LRUExpertTracker:
    def __init__(self, num_layers: int, num_experts: int):
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.last_used = np.zeros((num_layers, num_experts), np.int64)
        self.exec_counts = np.zeros((num_layers, num_experts), np.int64)
        self.step = 0

    def observe(self, layer: int, expert_ids) -> None:
        ids = np.asarray(expert_ids).reshape(-1)
        self.last_used[layer, ids] = self.step
        self.exec_counts[layer, ids] += 1

    def tick(self) -> None:
        self.step += 1

    def lru_order(self, layer: int) -> np.ndarray:
        """Expert ids, least-recently-used first (stable)."""
        return np.argsort(self.last_used[layer], kind="stable")

    def staleness(self, layer: int) -> np.ndarray:
        return self.step - self.last_used[layer]

    def mean_executed_per_node(self, n_nodes: int) -> float:
        """E[#executed experts/node/layer] over the observed trace — the
        paper's Table 1 statistic, fed to perf_model.estimate.  Experts are
        range-partitioned; a ragged last node is zero-padded."""
        e_per_node = -(-self.num_experts // n_nodes)        # ceil
        hits = (self.exec_counts > 0)
        pad = n_nodes * e_per_node - self.num_experts
        if pad:
            hits = np.pad(hits, ((0, 0), (0, pad)))
        hits = hits.reshape(self.num_layers, n_nodes, e_per_node)
        return float(hits.sum(axis=2).mean())


def quota_topup(selected_per_node: list[list[int]],
                lru_order_per_node: list[list[int]]) -> list[list[int]]:
    """Paper §4.2 Router-Aided Dynamic Loading, verbatim:

    every node tops its executed-expert set up to max(len(selected)) using
    its least-recently-used experts.  Returns the executed set per node.
    """
    quota = max(len(s) for s in selected_per_node)
    out = []
    for sel, lru in zip(selected_per_node, lru_order_per_node):
        execed = list(dict.fromkeys(sel))  # dedupe, keep order
        for e in lru:
            if len(execed) >= quota:
                break
            if e not in execed:
                execed.append(e)
        out.append(execed)
    return out


def simulate_expected_experts(num_experts: int, top_k: int, n_nodes: int,
                              n_tokens: int = 2048, n_layers: int = 8,
                              seed: int = 0, use_topup: bool = True) -> float:
    """Monte-Carlo estimate of E[#exec experts/node/layer] under uniform
    routing with (optionally) the L_R top-up — validates Table 1's measured
    2.65 / 2.32 / 1.57 within router-skew tolerance."""
    rng = np.random.default_rng(seed)
    e_per_node = num_experts // n_nodes
    tracker = [LRUExpertTracker(n_layers, e_per_node) for _ in range(n_nodes)]
    total = 0.0
    count = 0
    for _ in range(n_tokens):
        for layer in range(n_layers):
            choice = rng.choice(num_experts, size=top_k, replace=False)
            per_node = [[int(e - n * e_per_node) for e in choice
                         if n * e_per_node <= e < (n + 1) * e_per_node]
                        for n in range(n_nodes)]
            if use_topup:
                lrus = [t.lru_order(layer).tolist() for t in tracker]
                execed = quota_topup(per_node, lrus)
            else:
                execed = per_node
            for n, ex in enumerate(execed):
                if ex:
                    tracker[n].observe(layer, ex)
                total += len(ex)
                count += 1
        for t in tracker:
            t.tick()
    return total / count

"""Top-k MoE router (DBRX-style) with dead-expert masking and aux loss.

Dead-expert masking is how the framework handles expert counts that do not
divide the expert-parallel axis (e.g. granite's 40 experts padded to 48):
padded experts get -inf router logits so they are never selected, while the
parameter layout stays uniformly shardable — a static realization of the
paper's load-balancing theme (docs/DESIGN.md §4).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant

Array = jax.Array


class RouterOut(NamedTuple):
    top_idx: Array      # (T, K) int32 — selected expert ids
    top_w: Array        # (T, K) fp32 — combine weights (normalized if cfg says so)
    probs: Array        # (T, E) fp32 — full softmax (for aux loss / stats)
    aux_loss: Array     # () fp32 — Switch-style load-balance loss


def route(router_w: Array, x: Array, k: int, *,
          norm_topk: bool = True, n_valid_experts: int | None = None) -> RouterOut:
    """x: (T, D); router_w: (D, E). Returns top-k routing decisions.

    ``n_valid_experts``: if set (< E), experts >= n_valid are "dead" padding
    and receive -inf logits.  The router stays fp under the default weight
    store policy (core/quant.DEFAULT_KINDS), but a QuantTensor router (the
    per-kind override) is materialized here.
    """
    router_w = quant.materialize(router_w)
    e = router_w.shape[-1]
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    if n_valid_experts is not None and n_valid_experts < e:
        dead = jnp.arange(e) >= n_valid_experts
        logits = jnp.where(dead[None, :], -1e9, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, k)
    if norm_topk:
        top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    aux = load_balance_loss(probs, top_idx, e)
    return RouterOut(top_idx.astype(jnp.int32), top_w, probs, aux)


def load_balance_loss(probs: Array, top_idx: Array, num_experts: int) -> Array:
    """Switch-transformer aux loss, generalized to top-k."""
    t, k = top_idx.shape
    counts = jnp.zeros((num_experts,), jnp.float32).at[top_idx.reshape(-1)].add(1.0)
    f = counts / (t * k)                       # dispatch fraction per expert
    p = jnp.mean(probs, axis=0)                # mean router prob per expert
    return num_experts * jnp.sum(f * p)


def expected_experts_per_shard(top_idx: Array, num_experts: int,
                               n_shards: int) -> Array:
    """E[#distinct experts executed per shard] — the paper's Table 1 statistic
    (``E[#exec. experts/node/layer]``), computed from routing decisions."""
    eps = num_experts // n_shards
    hit = jnp.zeros((num_experts,), jnp.bool_).at[top_idx.reshape(-1)].set(True)
    per_shard = hit.reshape(n_shards, eps).sum(axis=1)
    return jnp.mean(per_shard.astype(jnp.float32))

"""MoE expert execution strategies — the paper's load-balancing methods as
static-shape TPU computations (docs/DESIGN.md §2, §5).

* ``dense``    — Busy Full Loading (L_B, paper §4.2): every expert computes
                 every token; unselected contributions are zeroed in the
                 weighted sum.  Zero dispatch overhead, E/k× extra FLOPs.
* ``dispatch`` — Router-Aided Dynamic Loading (L_R, paper §4.2) adapted to
                 SPMD: fixed-capacity dispatch.  Every shard executes an
                 identical, statically-shaped amount of expert work (the
                 "equalize to the max" half of L_R); token assignments above
                 capacity are dropped, below capacity padded (the LRU
                 freshness half is host-side, see core/dynamic_load.py).

All functions here operate on *local* expert shards: ``experts`` params carry
a leading E_local axis and ``e_start`` locates the shard in the global expert
space.  ``core/expert_parallel.py`` wraps them in shard_map.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import quant

Array = jax.Array


def round_capacity(tokens: int, k: int, num_experts: int,
                   capacity_factor: float, multiple: int = 8) -> int:
    """Static per-expert capacity, rounded up for MXU-friendly tiling."""
    raw = math.ceil(tokens * k / num_experts * capacity_factor)
    return max(multiple, math.ceil(raw / multiple) * multiple)


def expert_ffn(experts: dict, xe: Array, use_kernel: bool = False) -> Array:
    """Grouped SwiGLU FFN. xe: (E_local, C, D) -> (E_local, C, D).

    ``use_kernel`` selects the Pallas prestacked grouped-GEMM kernel
    (kernels/moe_gemm.py); default is the pure-jnp path (also the oracle).
    Expert weights may be raw arrays or blockwise-quantized QuantTensors
    (docs/DESIGN.md §8) — the jnp path dequantizes through the ``qdot``
    policy point, the kernel path dequantizes tiles in-VMEM.
    """
    if use_kernel:
        from repro.kernels import ops
        return ops.moe_ffn(xe, experts["w_gate"], experts["w_up"],
                           experts["w_down"])
    g = quant.qdot("ecd,edf->ecf", xe, experts["w_gate"],
                   preferred_element_type=jnp.float32)
    u = quant.qdot("ecd,edf->ecf", xe, experts["w_up"],
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(xe.dtype)
    return quant.qdot("ecf,efd->ecd", h, experts["w_down"],
                      preferred_element_type=jnp.float32).astype(xe.dtype)


# ---------------------------------------------------------------------------
# strategy: dense  (busy full loading, L_B)
# ---------------------------------------------------------------------------

def dense_moe(experts: dict, x: Array, top_idx: Array, top_w: Array,
              e_start: int, use_kernel: bool = False) -> Array:
    """x: (T, D). Every local expert computes every token; combine masks
    out everything the router did not select.  Returns the *local partial
    sum* (T, D) — caller psums across expert shards."""
    e_local = experts["w_gate"].shape[0]
    t = x.shape[0]
    xe = jnp.broadcast_to(x[None], (e_local, t, x.shape[1]))
    ye = expert_ffn(experts, xe, use_kernel)                # (E_local, T, D)
    # combine weight of local expert e for token t
    local_ids = e_start + jnp.arange(e_local)               # (E_local,)
    sel = top_idx[None, :, :] == local_ids[:, None, None]   # (E_local, T, K)
    w = jnp.sum(jnp.where(sel, top_w[None], 0.0), axis=-1)  # (E_local, T)
    return jnp.einsum("et,etd->td", w.astype(ye.dtype), ye)


# ---------------------------------------------------------------------------
# strategy: dispatch  (capacity-based, L_R)
# ---------------------------------------------------------------------------

def make_dispatch_plan(top_idx: Array, num_experts: int, e_start: int,
                       e_local: int, capacity: int):
    """Compute gather/scatter indices for capacity dispatch.

    Returns (dispatch_tok, slot_of, valid):
      dispatch_tok: (E_local * C,) int32 — source token per expert slot
                    (overflow/padding slots point at token 0 and are masked)
      slot_valid:   (E_local * C,) bool  — slot actually holds a token
      slot_of:      (T, K) int32 — destination slot per routing decision,
                    == E_local*C (one-past-end) when dropped / non-local
    """
    t, k = top_idx.shape
    flat_e = top_idx.reshape(-1)                            # (T*K,)
    order = jnp.argsort(flat_e, stable=True)                # group by expert
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(num_experts), side="left")
    rank = jnp.arange(t * k, dtype=jnp.int32) - first[sorted_e].astype(jnp.int32)
    is_local = (sorted_e >= e_start) & (sorted_e < e_start + e_local)
    ok = is_local & (rank < capacity)
    dest = (sorted_e - e_start) * capacity + rank           # (T*K,)
    nbuf = e_local * capacity
    dest = jnp.where(ok, dest, nbuf).astype(jnp.int32)

    dispatch_tok = jnp.zeros((nbuf + 1,), jnp.int32).at[dest].set(
        (order // k).astype(jnp.int32), mode="drop")
    slot_valid = jnp.zeros((nbuf + 1,), jnp.bool_).at[dest].set(
        True, mode="drop")
    slot_of = jnp.zeros((t * k,), jnp.int32).at[order].set(dest)
    return dispatch_tok[:nbuf], slot_valid[:nbuf], slot_of.reshape(t, k)


def dispatch_moe(experts: dict, x: Array, top_idx: Array, top_w: Array,
                 num_experts: int, e_start: int, capacity: int,
                 use_kernel: bool = False) -> Array:
    """Capacity-based dispatch on the local shard. x: (T, D) (all tokens
    visible locally — the decentralized design of paper §4.3). Returns the
    local partial sum (T, D); caller psums across expert shards."""
    e_local = experts["w_gate"].shape[0]
    t, d = x.shape
    dispatch_tok, slot_valid, slot_of = make_dispatch_plan(
        top_idx, num_experts, e_start, e_local, capacity)
    xe = x[dispatch_tok] * slot_valid[:, None].astype(x.dtype)
    xe = xe.reshape(e_local, capacity, d)
    ye = expert_ffn(experts, xe, use_kernel).reshape(e_local * capacity, d)
    ye_pad = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], axis=0)
    y_tk = ye_pad[slot_of]                                  # (T, K, D)
    return jnp.einsum("tk,tkd->td", top_w.astype(y_tk.dtype), y_tk)


# ---------------------------------------------------------------------------
# strategy: gather  (capacity-free decode fast path)
# ---------------------------------------------------------------------------

def gather_moe(experts: dict, x: Array, top_idx: Array, top_w: Array,
               e_start: int) -> Array:
    """Capacity-free per-token expert gather on the local shard.

    The dispatch path pays ``round_capacity``'s floor of 8 slots per expert
    no matter how few tokens arrive — a single-token decode step against E
    experts runs E·8 FFN rows of which at most K are real.  For small T·K
    (``cfg.gather_decode_max_tk``) this path instead gathers each token's
    selected expert weights directly (reference_moe's form, sharded): T·K
    FFN rows, zero padding, zero drops, and only the selected experts'
    weights are read — the decode analogue of the paper's observation that
    per-token expert *loads* dominate small-batch inference.

    x: (T, D).  Non-local selections (including ``_mask_rout``'s E_pad
    dead-route sentinel) contribute zero via a masked combine weight.
    Returns the local partial sum (T, D); caller psums across shards.
    ``use_kernel`` does not apply: the Pallas grouped GEMM wants the
    (E_local, C, D) capacity layout this path exists to avoid.  Quantized
    expert weights keep the path's defining property: ``QuantTensor[idx]``
    gathers only the selected experts' payload+scales, and only that
    gathered slice is dequantized."""
    e_local = experts["w_gate"].shape[0]
    local = (top_idx >= e_start) & (top_idx < e_start + e_local)
    idx = jnp.clip(top_idx - e_start, 0, e_local - 1)
    w = jnp.where(local, top_w, 0.0)
    g = quant.qdot("td,tkdf->tkf", x, experts["w_gate"][idx],
                   preferred_element_type=jnp.float32)
    u = quant.qdot("td,tkdf->tkf", x, experts["w_up"][idx],
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    y = quant.qdot("tkf,tkfd->tkd", h, experts["w_down"][idx],
                   preferred_element_type=jnp.float32)
    return jnp.einsum("tk,tkd->td", w.astype(jnp.float32),
                      y.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# single-device reference combine (used by tests as the oracle)
# ---------------------------------------------------------------------------

def reference_moe(experts: dict, x: Array, top_idx: Array, top_w: Array) -> Array:
    """Exact per-token top-k MoE (no capacity drops), pure gather form."""
    t, k = top_idx.shape
    wg, wu, wd = experts["w_gate"], experts["w_up"], experts["w_down"]

    def one_tok(xt, idx, w):
        g = quant.qdot("d,kdf->kf", xt, wg[idx], preferred_element_type=jnp.float32)
        u = quant.qdot("d,kdf->kf", xt, wu[idx], preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(xt.dtype)
        y = quant.qdot("kf,kfd->kd", h, wd[idx], preferred_element_type=jnp.float32)
        return jnp.einsum("k,kd->d", w, y.astype(jnp.float32)).astype(xt.dtype)

    return jax.vmap(one_tok)(x, top_idx, top_w)

"""Expert-wise weights prestacking (paper §4.1 / C2) — layout converters.

The canonical parameter layout in this framework is *prestacked*: every
weight kind is one contiguous array with leading (L[, E]) axes, scanned by
``lax.scan`` and consumed whole by the Pallas grouped-GEMM kernel.  The
naive layout ("unstacking", Fig. 4/5 baseline) keeps a python list of
per-layer dicts — more HLO, more dispatches, the TPU analogue of the
re-wiring-prone layout the paper measured on Metal.

These converters are used by the checkpoint pipeline (a one-time
preprocessing step, exactly like the paper's stacking script) and by the
Fig. 4 benchmark.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant


def unstack_blocks(blocks) -> list:
    """Prestacked blocks pytree (leading L axis) -> list of per-layer trees."""
    num_layers = jax.tree.leaves(blocks)[0].shape[0]
    return [jax.tree.map(lambda a: a[i], blocks) for i in range(num_layers)]


def stack_blocks(layer_list: list):
    """List of per-layer trees -> prestacked tree with leading L axis."""
    return jax.tree.map(lambda *a: jnp.stack(a), *layer_list)


def stack_experts(expert_list: list) -> dict:
    """List of per-expert {'w_gate','w_up','w_down'} -> stacked (E, ...)."""
    return jax.tree.map(lambda *a: jnp.stack(a), *expert_list)


def pad_experts(experts: dict, num_padded: int) -> dict:
    """Pad the expert axis with zero (router-dead) experts — granite's
    40 -> 48 padding (docs/DESIGN.md §4)."""
    e = jax.tree.leaves(experts)[0].shape[0]
    if e == num_padded:
        return experts
    assert e < num_padded

    def pad(a):
        widths = [(0, num_padded - e)] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, widths)

    return jax.tree.map(pad, experts)


def quantize_blocks(blocks, level: str = "none", *, block: int = 128,
                    kinds: tuple = quant.DEFAULT_KINDS):
    """Quantize a prestacked blocks tree into the blockwise weight store
    (core/quant.py, docs/DESIGN.md §8) — the second half of the one-time
    preprocessing step: stack once, quantize once, serve forever.  The
    identity at ``level='none'``; idempotent on already-quantized trees."""
    return quant.quantize_tree(blocks, level, block=block, kinds=kinds)


def validate_roundtrip(blocks) -> bool:
    """stack(unstack(x)) == x — used by tests and the ckpt converter."""
    rt = stack_blocks(unstack_blocks(blocks))
    ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), blocks, rt)
    return all(jax.tree.leaves(ok))

"""Expert-parallel execution of one MoE layer across the mesh.

Four collective schedules (docs/DESIGN.md §5):

* ``centralized``   — the paper's naive organization (Fig. 3): expert inputs
  flow through a center, 2 communications per layer.  SPMD realization:
  token activations are sequence-sharded over the expert axis, all-gathered
  to every expert shard (comm 1), and partial expert outputs are
  reduce-scattered back (comm 2).
* ``decentralized`` — the paper's P-*-D design (Fig. 7, GShard-inspired):
  attention + router replicated over the expert axis, experts sharded, one
  all-reduce (psum) on expert outputs per layer.
* ``a2a``           — beyond-paper schedule: tokens stay sequence-sharded,
  dispatch/combine use all_to_all so only top-k activations move, not the
  full token stream.  (What modern MoE stacks do; see EXPERIMENTS.md §Perf.)
* ``a2a_pipelined`` — a2a with comm/compute overlap: the local token block
  is split into ``cfg.ep_microchunks`` chunks and software-pipelined with a
  double-buffered scan — chunk i's expert FFN is independent of chunk i+1's
  dispatch all_to_all, so a latency-hiding scheduler can overlap them.
  Addresses the paper's central measurement that expert computation time ≈
  expert communication time (§5.2): pipelining hides the shorter of the
  two behind the longer (modelled analytically by
  core/perf_model.estimate(..., microchunks=m)).  Token-exact vs ``a2a``
  whenever capacity is not binding; per-chunk capacity is
  round_capacity(T_loc/m).

When the token count cannot be split over the expert axis (single-token
decode), ``centralized`` degrades to psum + a value-preserving ring
``ppermute`` so the *second* communication of the centralized design is
still present in the lowered HLO (cost-faithful; values unchanged), and
both a2a schedules fall back to ``decentralized``.

Quantized expert shards (core/quant.QuantTensor leaves, docs/DESIGN.md §8)
ride every schedule unchanged: the int8/int4 payload and its per-block
scales are sibling rank-3 leaves sharing the leading expert axis, so
``_expert_specs``'s rank-3 PartitionSpecs broadcast over both and the
shard_map bodies receive local QuantTensor shards.  Activations stay fp —
dispatch/combine collectives move fp activations only; dequantization
happens at the expert FFN's ``qdot`` policy point (core/moe.expert_ffn).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import moe as moe_lib
from repro.core import router as router_lib

Array = jax.Array


def mesh_batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


EXPERT_AXIS = "model"


def _local_moe(cfg, experts: dict, x2d: Array, rout: router_lib.RouterOut,
               e_start, capacity: int) -> Array:
    if cfg.moe_strategy == "dense":
        return moe_lib.dense_moe(experts, x2d, rout.top_idx, rout.top_w,
                                 e_start, cfg.use_kernel)
    t, k = rout.top_idx.shape
    e_local = experts["w_gate"].shape[0]
    if 0 < t * k <= getattr(cfg, "gather_decode_max_tk", 0):
        # capacity-free decode fast path (no round_capacity floor, no
        # dispatch plan, no drops), form chosen by modeled cost:
        #  * per-token gather when T*K <= E_local — reads only the selected
        #    experts' weights (< one full local shard);
        #  * one-hot dense compute when T is below the capacity floor —
        #    same weight traffic as dispatch but E_local*T FFN rows instead
        #    of E_local*C mostly-padding slots, and none of the
        #    argsort/scatter plan overhead.
        # Above both cut-offs the fixed-capacity dispatch is already the
        # cheaper layout and wins.
        if t * k <= e_local:
            return moe_lib.gather_moe(experts, x2d, rout.top_idx, rout.top_w,
                                      e_start)
        if t < capacity:
            return moe_lib.dense_moe(experts, x2d, rout.top_idx, rout.top_w,
                                     e_start, cfg.use_kernel)
    return moe_lib.dispatch_moe(experts, x2d, rout.top_idx, rout.top_w,
                                cfg.num_experts_padded, e_start, capacity,
                                cfg.use_kernel)


def _mask_rout(rout: router_lib.RouterOut, valid: Array,
               e_pad: int) -> router_lib.RouterOut:
    """Dead-route invalid tokens: padding/garbage batch rows must consume
    ZERO expert capacity (the batched-prefill engine recomputes in-flight
    and empty slots under a mask — without this their tokens would crowd
    real tokens out of the fixed-capacity dispatch)."""
    top_idx = jnp.where(valid[:, None], rout.top_idx, e_pad)
    top_w = jnp.where(valid[:, None], rout.top_w, 0.0)
    return rout._replace(top_idx=top_idx.astype(jnp.int32), top_w=top_w)


def moe_layer(cfg, mesh, layer_p: dict, x: Array, token_mask: Array | None = None
              ) -> tuple[Array, Array, Array]:
    """Apply one MoE layer.

    x: (B, S, D) -> (y (B, S, D), aux_loss (), top_idx (B*S, K) int32).

    ``top_idx`` is the layer's *actual* routing decision per token — the
    device-side capture the serving engine's ``LRUExpertTracker`` consumes
    (paper Table 1, E[#exec experts/node/layer]) instead of re-running the
    router on the host.  With overlapping expert placement (r > 1) it is
    the pre-stripe decision: which experts each token selected, not which
    replica served it.

    ``token_mask``: optional (B, S) bool — False tokens are dead-routed to
    the padding sentinel (index E_pad): they consume no expert capacity,
    produce zero MoE output, and appear as E_pad in ``top_idx``.

    ``layer_p``: {"router": (D, E_pad), "experts": {"w_gate": (E_pad, D, F),
    "w_up": ..., "w_down": ...}} — per-layer slices of the prestacked stack.
    """
    b, s, d = x.shape
    k = cfg.experts_per_token
    r = max(getattr(cfg, "expert_replication", 1), 1)
    if mesh is None or EXPERT_AXIS not in getattr(mesh, "axis_names", ()):
        # single-shard path (smoke tests / CPU examples); with overlapping
        # placement the stacked array carries r copies — use the first
        experts = layer_p["experts"]
        if r > 1:
            experts = jax.tree.map(
                lambda a: a[:cfg.num_experts_padded], experts)
        x2d = x.reshape(b * s, d)
        rout = router_lib.route(layer_p["router"], x2d, cfg.experts_per_token,
                                norm_topk=cfg.router_norm_topk,
                                n_valid_experts=cfg.num_experts)
        if token_mask is not None:
            rout = _mask_rout(rout, token_mask.reshape(b * s),
                              cfg.num_experts_padded)
        cap = moe_lib.round_capacity(b * s, cfg.experts_per_token,
                                     cfg.num_experts_padded,
                                     cfg.capacity_factor)
        y = _local_moe(cfg, experts, x2d, rout, 0, cap)
        return y.reshape(b, s, d), rout.aux_loss, rout.top_idx

    n_exp_shards = mesh.shape[EXPERT_AXIS]
    if r > 1:
        assert cfg.expert_parallel == "decentralized", (
            "overlapping expert placement (paper §5.3) is implemented on "
            "the decentralized schedule")
        assert n_exp_shards % r == 0, (n_exp_shards, r)
        assert (cfg.num_experts_padded * r) % n_exp_shards == 0
    e_local = cfg.num_experts_padded * r // n_exp_shards
    batch_axes = mesh_batch_axes(mesh)
    # only shard the batch dim if it divides the data axes (long_500k has b=1)
    if b % max(_axes_size(mesh, batch_axes), 1) != 0:
        batch_axes = ()

    if token_mask is None:
        token_mask = jnp.ones((b, s), jnp.bool_)
    fn = {"decentralized": _decentralized, "centralized": _centralized,
          "a2a": _a2a, "a2a_pipelined": _a2a_pipelined}[cfg.expert_parallel]
    y, aux, top_idx = fn(cfg, mesh, layer_p, x, token_mask, batch_axes,
                         n_exp_shards, e_local)
    return y, aux, top_idx.reshape(b * s, k)


def _axes_size(mesh, axes) -> int:
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def _expert_specs(e_axis: str) -> dict:
    return {"w_gate": P(e_axis, None, None), "w_up": P(e_axis, None, None),
            "w_down": P(e_axis, None, None)}


def _route_local(cfg, layer_p, x2d):
    return router_lib.route(layer_p_router(layer_p), x2d,
                            cfg.experts_per_token,
                            norm_topk=cfg.router_norm_topk,
                            n_valid_experts=cfg.num_experts)


def layer_p_router(layer_p):
    return layer_p["router"]


# ---------------------------------------------------------------------------
# decentralized (paper Fig. 7): replicated tokens, sharded experts, one psum
# ---------------------------------------------------------------------------

def _decentralized(cfg, mesh, layer_p, x, token_mask, batch_axes, n_shards,
                   e_local):
    """Paper Fig. 7, plus the paper's §5.3 *overlapping expert placement*:
    with ``cfg.expert_replication = r > 1`` every expert is stored on r
    shards (the stacked expert array carries r concatenated copies — "use
    the extra memory to load experts overlappingly") and each replica
    handles the 1/r token stripe ``token_idx % r == replica_id``, which is
    how the paper distributes computation more evenly past 4 nodes."""
    b, s, _ = x.shape
    r = max(getattr(cfg, "expert_replication", 1), 1)
    t_loc = max((b * s) // max(_axes_size(mesh, batch_axes), 1), 1)
    cap = moe_lib.round_capacity(-(-t_loc // r), cfg.experts_per_token,
                                 cfg.num_experts_padded, cfg.capacity_factor)
    e_pad = cfg.num_experts_padded
    n_grp = n_shards // r           # shards per expert copy

    def body(router_w, experts, x_loc, tm_loc):
        bl, sl, d = x_loc.shape
        x2d = x_loc.reshape(bl * sl, d)
        rout = router_lib.route(router_w, x2d, cfg.experts_per_token,
                                norm_topk=cfg.router_norm_topk,
                                n_valid_experts=cfg.num_experts)
        rout = _mask_rout(rout, tm_loc.reshape(bl * sl), e_pad)
        routed = rout.top_idx            # pre-stripe: actual decisions
        idx = jax.lax.axis_index(EXPERT_AXIS)
        if r > 1:
            replica = idx // n_grp
            e_start = (idx % n_grp) * e_local
            stripe = (jnp.arange(bl * sl) % r) == replica
            # tokens outside this replica's stripe route to a dead sentinel
            top_idx = jnp.where(stripe[:, None], rout.top_idx, e_pad)
            top_w = jnp.where(stripe[:, None], rout.top_w, 0.0)
            rout = rout._replace(top_idx=top_idx.astype(jnp.int32),
                                 top_w=top_w)
        else:
            e_start = idx * e_local
        y = _local_moe(cfg, experts, x2d, rout, e_start, cap)
        y = jax.lax.psum(y, EXPERT_AXIS)
        aux = jax.lax.pmean(rout.aux_loss, batch_axes) if batch_axes else rout.aux_loss
        return (y.reshape(bl, sl, d), aux,
                routed.reshape(bl, sl, cfg.experts_per_token))

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(), _expert_specs(EXPERT_AXIS), P(batch_axes, None, None),
                  P(batch_axes, None)),
        out_specs=(P(batch_axes, None, None), P(), P(batch_axes, None, None)),
        check_vma=True,
    )(layer_p["router"], layer_p["experts"], x, token_mask)


# ---------------------------------------------------------------------------
# centralized (paper Fig. 3): 2 communications per layer
# ---------------------------------------------------------------------------

def _centralized(cfg, mesh, layer_p, x, token_mask, batch_axes, n_shards,
                 e_local):
    b, s, d = x.shape
    e_pad = cfg.num_experts_padded
    seq_shardable = s % n_shards == 0
    t_per_batch_shard = (b // max(_axes_size(mesh, batch_axes), 1)) * s
    cap = moe_lib.round_capacity(max(t_per_batch_shard, 1),
                                 cfg.experts_per_token,
                                 cfg.num_experts_padded, cfg.capacity_factor)

    if seq_shardable:
        def body(router_w, experts, x_loc, tm_loc):
            bl, sl, dd = x_loc.shape
            # comm 1: gather the full token stream to every expert shard
            x_full = jax.lax.all_gather(x_loc, EXPERT_AXIS, axis=1, tiled=True)
            x2d = x_full.reshape(bl * sl * n_shards, dd)
            tm_full = jax.lax.all_gather(tm_loc, EXPERT_AXIS, axis=1,
                                         tiled=True)
            rout = router_lib.route(router_w, x2d, cfg.experts_per_token,
                                    norm_topk=cfg.router_norm_topk,
                                    n_valid_experts=cfg.num_experts)
            rout = _mask_rout(rout, tm_full.reshape(bl * sl * n_shards), e_pad)
            e_start = jax.lax.axis_index(EXPERT_AXIS) * e_local
            y = _local_moe(cfg, experts, x2d, rout, e_start, cap)
            # comm 2: reduce partial sums and scatter back to sequence shards
            y = y.reshape(bl, sl * n_shards, dd)
            y = jax.lax.psum_scatter(y, EXPERT_AXIS, scatter_dimension=1,
                                     tiled=True)
            aux = jax.lax.pmean(rout.aux_loss, (EXPERT_AXIS,) + tuple(batch_axes))
            # every shard routed the full gathered stream — emit this
            # shard's own sequence slice, globally reassembled by out_specs
            ti = rout.top_idx.reshape(bl, sl * n_shards, cfg.experts_per_token)
            ti = jax.lax.dynamic_slice_in_dim(
                ti, jax.lax.axis_index(EXPERT_AXIS) * sl, sl, axis=1)
            return y, aux, ti

        return compat.shard_map(
            body, mesh=mesh,
            in_specs=(P(), _expert_specs(EXPERT_AXIS),
                      P(batch_axes, EXPERT_AXIS, None),
                      P(batch_axes, EXPERT_AXIS)),
            out_specs=(P(batch_axes, EXPERT_AXIS, None), P(),
                       P(batch_axes, EXPERT_AXIS, None)),
            check_vma=True,
        )(layer_p["router"], layer_p["experts"], x, token_mask)

    # decode fallback: psum (comm 1) + value-preserving ring permute (comm 2)
    def body(router_w, experts, x_loc, tm_loc):
        bl, sl, dd = x_loc.shape
        x2d = x_loc.reshape(bl * sl, dd)
        rout = router_lib.route(router_w, x2d, cfg.experts_per_token,
                                norm_topk=cfg.router_norm_topk,
                                n_valid_experts=cfg.num_experts)
        rout = _mask_rout(rout, tm_loc.reshape(bl * sl), e_pad)
        e_start = jax.lax.axis_index(EXPERT_AXIS) * e_local
        y = _local_moe(cfg, experts, x2d, rout, e_start, cap)
        y = jax.lax.psum(y, EXPERT_AXIS)
        perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]
        y = jax.lax.ppermute(y, EXPERT_AXIS, perm)  # identical values move
        aux = jax.lax.pmean(rout.aux_loss, batch_axes) if batch_axes else rout.aux_loss
        return (y.reshape(bl, sl, dd), aux,
                rout.top_idx.reshape(bl, sl, cfg.experts_per_token))

    # check_vma=False: the ring ppermute moves identical values, so the
    # output *is* replicated over the expert axis, but VMA cannot prove it.
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(), _expert_specs(EXPERT_AXIS), P(batch_axes, None, None),
                  P(batch_axes, None)),
        out_specs=(P(batch_axes, None, None), P(), P(batch_axes, None, None)),
        check_vma=False,
    )(layer_p["router"], layer_p["experts"], x, token_mask)


# ---------------------------------------------------------------------------
# a2a (beyond paper): sequence-sharded tokens + all_to_all dispatch/combine
# ---------------------------------------------------------------------------

def _a2a_dispatch(cfg, xi, ti, n_shards, e_local, cap):
    """Token-block dispatch: capacity plan + gather + all_to_all (comm 1).

    Shared by ``_a2a`` (whole local block) and ``_a2a_pipelined`` (one
    microchunk) — the plan builds buffers for *all* experts, grouped by
    owner shard, so shard i's slice j travels to shard j.  Returns the
    post-exchange (n_src_shards, e_local*cap, d) buffer of local-expert
    inputs plus ``slot_of`` for the combine."""
    dd = xi.shape[-1]
    dispatch_tok, slot_valid, slot_of = moe_lib.make_dispatch_plan(
        ti, cfg.num_experts_padded, 0, cfg.num_experts_padded, cap)
    xe = xi[dispatch_tok] * slot_valid[:, None].astype(xi.dtype)
    xe = xe.reshape(n_shards, e_local * cap, dd)
    xe = jax.lax.all_to_all(xe, EXPERT_AXIS, split_axis=0, concat_axis=0,
                            tiled=False)
    return xe, slot_of


def _a2a_ffn_combine(cfg, experts, xe, slot_of, wi, n_shards, e_local, cap):
    """Token-block compute: expert FFN + return all_to_all (comm 2) +
    weighted combine back into source-token order (shared by ``_a2a`` and
    ``_a2a_pipelined``)."""
    dd = xe.shape[-1]
    xe = xe.transpose(1, 0, 2).reshape(e_local, n_shards * cap, dd)
    ye = moe_lib.expert_ffn(experts, xe, cfg.use_kernel)
    # invert (e_local, cap*n_src) -> (n_src, e_local*cap) exactly
    ye = ye.reshape(e_local, cap, n_shards, dd).transpose(2, 0, 1, 3)
    ye = ye.reshape(n_shards, e_local * cap, dd)
    ye = jax.lax.all_to_all(ye, EXPERT_AXIS, split_axis=0, concat_axis=0,
                            tiled=False)
    ye = ye.reshape(n_shards * e_local * cap, dd)
    ye_pad = jnp.concatenate([ye, jnp.zeros((1, dd), ye.dtype)], axis=0)
    y_tk = ye_pad[slot_of]
    return jnp.einsum("tk,tkd->td", wi.astype(y_tk.dtype), y_tk)


def _a2a(cfg, mesh, layer_p, x, token_mask, batch_axes, n_shards, e_local):
    b, s, d = x.shape
    if s % n_shards != 0:
        # single-token decode: fall back to the decentralized schedule
        return _decentralized(cfg, mesh, layer_p, x, token_mask, batch_axes,
                              n_shards, e_local)
    t_loc = (b // max(_axes_size(mesh, batch_axes), 1)) * (s // n_shards)
    # per-(source shard, expert) capacity
    cap = moe_lib.round_capacity(max(t_loc, 1), cfg.experts_per_token,
                                 cfg.num_experts_padded, cfg.capacity_factor)

    def body(router_w, experts, x_loc, tm_loc):
        bl, sl, dd = x_loc.shape
        x2d = x_loc.reshape(bl * sl, dd)
        rout = router_lib.route(router_w, x2d, cfg.experts_per_token,
                                norm_topk=cfg.router_norm_topk,
                                n_valid_experts=cfg.num_experts)
        rout = _mask_rout(rout, tm_loc.reshape(bl * sl),
                          cfg.num_experts_padded)
        xe, slot_of = _a2a_dispatch(cfg, x2d, rout.top_idx, n_shards,
                                    e_local, cap)
        y = _a2a_ffn_combine(cfg, experts, xe, slot_of, rout.top_w,
                             n_shards, e_local, cap)
        aux = jax.lax.pmean(rout.aux_loss, (EXPERT_AXIS,) + tuple(batch_axes))
        return (y.reshape(bl, sl, dd), aux,
                rout.top_idx.reshape(bl, sl, cfg.experts_per_token))

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(), _expert_specs(EXPERT_AXIS),
                  P(batch_axes, EXPERT_AXIS, None),
                  P(batch_axes, EXPERT_AXIS)),
        out_specs=(P(batch_axes, EXPERT_AXIS, None), P(),
                   P(batch_axes, EXPERT_AXIS, None)),
        check_vma=True,
    )(layer_p["router"], layer_p["experts"], x, token_mask)


# ---------------------------------------------------------------------------
# a2a_pipelined: microchunked a2a with comm/compute overlap
# ---------------------------------------------------------------------------

def _a2a_pipelined(cfg, mesh, layer_p, x, token_mask, batch_axes, n_shards,
                   e_local):
    """Software-pipelined a2a: the local token block is split into
    ``cfg.ep_microchunks`` chunks, and a double-buffered ``lax.scan`` keeps
    one chunk's dispatched activations in flight while the previous chunk's
    expert FFN runs — within each scan step, ``dispatch(chunk i+1)`` (the
    all_to_all) has no data dependency on ``ffn_combine(chunk i)`` (the
    expert GEMMs), which is exactly the structure XLA's latency-hiding
    scheduler needs to overlap collective DMA with compute.  The paper
    measures expert comm ≈ expert compute (§5.2); this schedule bounds the
    layer at max(comm, compute) + min(comm, compute)/m instead of their sum
    (see core/perf_model.estimate(..., microchunks=m)).

    Per-chunk capacity is ``round_capacity(T_loc/m)``, so routing and
    per-slot contractions are identical to ``a2a`` whenever capacity is not
    binding — token-exact end-to-end (outputs differ only by XLA's
    reduction-order reassociation at the chunked GEMM shapes, <1e-6, which
    never flips a greedy token; both properties are asserted in
    tests/distributed_checks.py).  Falls back to ``_a2a`` when the chunk
    split does not divide, which itself falls back to ``_decentralized``
    for single-token decode."""
    b, s, d = x.shape
    if s % n_shards != 0:
        # single-token decode: same fallback as _a2a
        return _decentralized(cfg, mesh, layer_p, x, token_mask, batch_axes,
                              n_shards, e_local)
    m = max(getattr(cfg, "ep_microchunks", 1), 1)
    t_loc = (b // max(_axes_size(mesh, batch_axes), 1)) * (s // n_shards)
    if m <= 1 or t_loc % m != 0 or t_loc // m < 1:
        return _a2a(cfg, mesh, layer_p, x, token_mask, batch_axes, n_shards,
                    e_local)
    k = cfg.experts_per_token
    e_pad = cfg.num_experts_padded
    # per-(source shard, chunk, expert) capacity
    cap = moe_lib.round_capacity(t_loc // m, k, e_pad, cfg.capacity_factor)

    def body(router_w, experts, x_loc, tm_loc):
        bl, sl, dd = x_loc.shape
        t = bl * sl
        x2d = x_loc.reshape(t, dd)
        rout = router_lib.route(router_w, x2d, k,
                                norm_topk=cfg.router_norm_topk,
                                n_valid_experts=cfg.num_experts)
        rout = _mask_rout(rout, tm_loc.reshape(t), e_pad)
        tc = t // m
        xc = x2d.reshape(m, tc, dd)
        ic = rout.top_idx.reshape(m, tc, k)
        wc = rout.top_w.reshape(m, tc, k)
        dispatch = lambda xi, ti: _a2a_dispatch(cfg, xi, ti, n_shards,
                                                e_local, cap)
        ffn_combine = lambda xe, so, wi: _a2a_ffn_combine(
            cfg, experts, xe, so, wi, n_shards, e_local, cap)

        # double-buffered pipeline: the carry holds chunk i's in-flight
        # dispatched buffer; each step issues chunk i+1's dispatch BEFORE
        # consuming chunk i, so the two can overlap
        xe0, so0 = dispatch(xc[0], ic[0])

        def step(carry, nxt):
            xe_i, so_i, w_i = carry
            x_n, i_n, w_n = nxt
            xe_next, so_next = dispatch(x_n, i_n)      # comm for chunk i+1
            y_i = ffn_combine(xe_i, so_i, w_i)         # compute for chunk i
            return (xe_next, so_next, w_n), y_i

        (xe_l, so_l, w_l), ys = jax.lax.scan(
            step, (xe0, so0, wc[0]), (xc[1:], ic[1:], wc[1:]))
        y_last = ffn_combine(xe_l, so_l, w_l)          # drain the pipeline
        y = jnp.concatenate([ys.reshape((m - 1) * tc, dd), y_last], axis=0)
        aux = jax.lax.pmean(rout.aux_loss, (EXPERT_AXIS,) + tuple(batch_axes))
        return (y.reshape(bl, sl, dd), aux,
                rout.top_idx.reshape(bl, sl, k))

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(), _expert_specs(EXPERT_AXIS),
                  P(batch_axes, EXPERT_AXIS, None),
                  P(batch_axes, EXPERT_AXIS)),
        out_specs=(P(batch_axes, EXPERT_AXIS, None), P(),
                   P(batch_axes, EXPERT_AXIS, None)),
        check_vma=True,
    )(layer_p["router"], layer_p["experts"], x, token_mask)

"""The paper's analytical performance model (§4.4, Eq. 1) — generalized.

Per-token lower-bound inference time for an expert-parallel MoE system:

    T = max(bytes_loaded / mem_bw, FLOPs / peak_flops)        (GPU term)
      + n_layers * comm_latency + comm_bytes / comm_bw        (comm term)

The module reproduces Table 1 (DBRX variable derivations), Table 6
(estimated bounds for 2–8 Mac Studio nodes over 10 GbE), Fig. 8's RDMA NIC
projections, and Table 5's cost-efficiency comparison.  Beyond the paper,
``estimate(..., microchunks=m)`` extends Eq. (1) with a comm/compute
overlap term modelling the ``a2a_pipelined`` schedule
(core/expert_parallel): serial gpu+comm becomes the pipelined bound
m·latency + max(gpu, transfer) + min(gpu, transfer)/m; and
``mixed_step_estimate``/``chunked_prefill_ttft`` model the unified
mixed prefill/decode iteration (serving/engine.py ``unified_step``,
docs/DESIGN.md §6) with a ``chunk_len`` knob — the prefill chunk rides on
expert weights the decode rows already load, so interleaving is nearly
free in the load-bound regime while smaller chunks add latency rounds.  The same equation
parameterized with TPU v5e constants is the seed of the roofline analysis
in benchmarks/roofline.py (compute/memory terms from the compiled HLO
replace the napkin FLOPs/bytes; the comm term becomes the collective term).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    mem_bw: float              # bytes/sec per node
    peak_flops: float          # FLOP/s per node (bf16)
    comm_latency: float        # sec per communication round
    comm_bw: float             # bytes/sec
    price_per_node: float = 0.0  # USD


# paper Table 1 / Table 2 / §5.5 footnotes
M2_ULTRA_10GBE = HardwareProfile(
    "mac-studio-10gbe", mem_bw=800e9, peak_flops=54e12,
    comm_latency=1e-3, comm_bw=1.25e9, price_per_node=6599.0)
M2_ULTRA_ROCE = HardwareProfile(
    "mac-studio-rocev2", mem_bw=800e9, peak_flops=54e12,
    comm_latency=750e-9, comm_bw=25e9 / 8, price_per_node=6599.0 + 339.0)
M2_ULTRA_IB = HardwareProfile(
    "mac-studio-infiniband", mem_bw=800e9, peak_flops=54e12,
    comm_latency=600e-9, comm_bw=200e9 / 8, price_per_node=6599.0 + 1267.0)
# target hardware of this reproduction (per-chip)
TPU_V5E = HardwareProfile(
    "tpu-v5e", mem_bw=819e9, peak_flops=197e12,
    comm_latency=1e-6, comm_bw=50e9)
# Table 5 baseline
DGX_H100x8 = HardwareProfile(
    "dgx-8xh100", mem_bw=8 * 3.35e12, peak_flops=8 * 989e12,
    comm_latency=2e-6, comm_bw=450e9, price_per_node=289_000.0)


@dataclasses.dataclass(frozen=True)
class MoEWorkload:
    """Per-token workload description (paper Table 1, derived from config)."""
    n_layers: int
    params_sa_bytes: float     # self-attention (+router/norm) weight bytes
    flops_sa: float
    params_expert_bytes: float # one expert's weight bytes (all layers)
    flops_expert: float
    comm_bytes: float          # all-reduce payload per token (all layers)

    @classmethod
    def from_config(cls, cfg, precision: int = 2) -> "MoEWorkload":
        """Derive Table-1-style variables from a ModelConfig (per token)."""
        d, L = cfg.d_model, cfg.num_layers
        qkv_hidden = (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim
        p_sa = (qkv_hidden * d + d * d_out_attn(cfg)) * L * precision
        # Table 1 footnote (c): FLOPs_SA = 2 x #Params_SA where Params_SA is
        # in BYTES — the paper's convention (14e9 for DBRX), kept verbatim
        # for fidelity; harmless since Eq. 1 is load-bound on this hardware.
        f_sa = 2.0 * p_sa
        if cfg.is_moe:
            p_e = d * cfg.d_ff * 3 * L * precision
            f_e = 2 * d * cfg.d_ff * 3 * L
        else:
            p_e, f_e = d * cfg.d_ff * 3 * L * precision, 2 * d * cfg.d_ff * 3 * L
        comm = d * 4 * L * precision
        return cls(L, p_sa, f_sa, p_e, f_e, comm)


def d_out_attn(cfg) -> int:
    return cfg.num_heads * cfg.head_dim


# paper Table 1 measured routing statistic: E[#executed experts/node/layer].
# 2/3/4 nodes are measured (Table 1); 6/8 are the values implied by Table 6's
# load column ((load*mem_bw - params_SA)/params_expert), since the paper
# extrapolates them with its overlapped expert placement.
PAPER_EXPECTED_EXPERTS = {2: 2.65, 3: 2.32, 4: 1.57, 6: 1.1125, 8: 1.0125}


def expected_experts_per_node(num_experts: int, top_k: int, n_nodes: int,
                              batch: int = 1) -> float:
    """E[#distinct local experts hit per node per layer] under uniform
    routing of ``batch`` tokens: each of the E/n local experts is selected by
    one token w.p. k/E, so hit w.p. 1-(1-k/E)^batch.  With batch=1 this is
    k/n — the analytic floor under the paper's measured values (Table 1's
    2.65/2.32/1.57 include router skew and the L_R LRU top-up)."""
    e_per_node = num_experts / n_nodes
    p_hit = 1.0 - (1.0 - top_k / num_experts) ** batch
    return e_per_node * p_hit


DBRX_TABLE1 = MoEWorkload(
    n_layers=40,
    params_sa_bytes=7e9, flops_sa=14e9,
    params_expert_bytes=16e9, flops_expert=16e9,
    comm_bytes=2e6,
)


@dataclasses.dataclass(frozen=True)
class Estimate:
    load_time: float
    compute_time: float
    latency_time: float
    transfer_time: float
    # comm/compute overlap term: >1 models the a2a_pipelined schedule
    # (core/expert_parallel), which splits the token block into m
    # microchunks and overlaps chunk i's expert FFN with chunk i+1's
    # dispatch.  Eq. (1)'s serial sum gpu + comm then becomes the two-stage
    # pipeline bound  m·latency + max(gpu, transfer) + min(gpu, transfer)/m:
    # the slower stage is exposed in full, the faster one only through its
    # un-overlapped first chunk, and every microchunk round pays the
    # per-layer collective latency.  m = 1 reproduces the paper's serial
    # Eq. (1) exactly (Tables 5/6).
    microchunks: int = 1

    @property
    def gpu_time(self) -> float:
        return max(self.load_time, self.compute_time)

    @property
    def comm_time(self) -> float:
        return self.latency_time + self.transfer_time

    @property
    def total(self) -> float:
        m = self.microchunks
        if m <= 1:
            return self.gpu_time + self.comm_time
        g, t = self.gpu_time, self.transfer_time
        return self.latency_time * m + max(g, t) + min(g, t) / m

    @property
    def throughput(self) -> float:
        return 1.0 / self.total


def estimate(w: MoEWorkload, hw: HardwareProfile, n_nodes: int,
             expected_experts: float | None = None,
             microchunks: int = 1) -> Estimate:
    """Paper Eq. (1): per-token generation lower bound on n_nodes.

    ``microchunks`` > 1 applies the a2a_pipelined overlap term (see
    ``Estimate.microchunks``); the default reproduces the paper's serial
    bound."""
    if expected_experts is None:
        expected_experts = PAPER_EXPECTED_EXPERTS.get(
            n_nodes, expected_experts_per_node(16, 4, n_nodes))
    bytes_loaded = w.params_sa_bytes + w.params_expert_bytes * expected_experts
    flops = w.flops_sa + w.flops_expert * expected_experts
    return Estimate(
        load_time=bytes_loaded / hw.mem_bw,
        compute_time=flops / hw.peak_flops,
        latency_time=hw.comm_latency * w.n_layers,
        transfer_time=w.comm_bytes / hw.comm_bw,
        microchunks=microchunks,
    )


def scaling_table(w: MoEWorkload = DBRX_TABLE1,
                  hw: HardwareProfile = M2_ULTRA_10GBE,
                  nodes: tuple = (2, 3, 4, 6, 8),
                  microchunks: int = 1) -> list[dict]:
    """Reproduces paper Table 6 (and the green triangles of Fig. 8).

    ``microchunks`` > 1 adds the a2a_pipelined overlap columns
    (``bound_s_pipelined`` / ``tokens_per_sec_pipelined``) next to the
    paper's serial bound, so Table 5/6-style estimates can model the
    overlapped schedule."""
    rows = []
    for n in nodes:
        e = estimate(w, hw, n)
        row = {
            "nodes": n, "load_s": e.load_time, "comp_s": e.compute_time,
            "lat_s": e.latency_time, "trans_s": e.transfer_time,
            "bound_s": e.total, "tokens_per_sec": e.throughput,
            # Table 6 prints Time rounded to 3 decimals and derives TP from
            # the rounded value (e.g. 3 nodes: 1/0.096 = 10.4)
            "tokens_per_sec_table6": 1.0 / round(e.total, 3),
        }
        if microchunks > 1:
            ep = dataclasses.replace(e, microchunks=microchunks)
            row["bound_s_pipelined"] = ep.total
            row["tokens_per_sec_pipelined"] = ep.throughput
        rows.append(row)
    return rows


def mixed_step_estimate(w: MoEWorkload, hw: HardwareProfile, n_nodes: int,
                        decode_rows: int, chunk_len: int,
                        num_experts: int = 16, top_k: int = 4,
                        microchunks: int = 1) -> Estimate:
    """Per-ITERATION bound for the unified mixed prefill/decode batch
    (serving/engine.py ``unified_step``): ``decode_rows`` decode tokens plus
    one ``chunk_len``-token prefill chunk share a single program.

    Eq. (1) is per *token*; a mixed iteration amortizes the weight-load
    term across all t = decode_rows + chunk_len tokens in the block — the
    expected number of DISTINCT experts touched grows sublinearly in t
    (``expected_experts_per_node`` with batch=t) while FLOPs and comm
    payload scale linearly.  This is exactly why interleaving prefill
    chunks into decode batches is nearly free on load-bound hardware (the
    paper's regime): the chunk rides on weights the decode rows already
    paid to load.  ``chunk_len=0`` recovers the decode-only iteration."""
    t = max(decode_rows + chunk_len, 1)
    per_node = expected_experts_per_node(num_experts, top_k, n_nodes,
                                         batch=t)
    bytes_loaded = w.params_sa_bytes + w.params_expert_bytes * per_node
    # Per-NODE FLOPs, matching estimate()'s Eq. (1) convention: the shared
    # layers run on every node (w.flops_sa per token), while the t*top_k
    # token-expert FFN pairs spread across the n_nodes expert shards
    # (w.flops_expert is one expert's FFN over all layers, per token)
    flops = w.flops_sa * t + w.flops_expert * top_k * t / n_nodes
    return Estimate(
        load_time=bytes_loaded / hw.mem_bw,
        compute_time=flops / hw.peak_flops,
        latency_time=hw.comm_latency * w.n_layers,
        transfer_time=w.comm_bytes * t / hw.comm_bw,
        microchunks=microchunks,
    )


def chunked_prefill_ttft(w: MoEWorkload, hw: HardwareProfile, n_nodes: int,
                         prompt_len: int, chunk_len: int,
                         decode_rows: int = 0, num_experts: int = 16,
                         top_k: int = 4) -> float:
    """Modelled time-to-first-token of a ``prompt_len`` prompt streamed in
    ``chunk_len`` chunks through iterations shared with ``decode_rows``
    in-flight decode rows: ceil(P/c) mixed iterations, the last of which
    samples token 1.  Shrinking ``chunk_len`` lowers the per-iteration
    latency decode rows see but adds iterations (each paying the per-layer
    collective latency) — the knob the unified scheduler's ``token_budget``
    exposes."""
    iters = max(-(-prompt_len // max(chunk_len, 1)), 1)
    last = prompt_len - (iters - 1) * chunk_len
    total = 0.0
    for i in range(iters):
        c = chunk_len if i < iters - 1 else last
        total += mixed_step_estimate(w, hw, n_nodes, decode_rows, c,
                                     num_experts, top_k).total
    return total


# ---------------------------------------------------------------------------
# memory-capacity term (paper Table 2's unified-memory budget) and the
# paged-KV-cache serving model (serving/engine.py EngineConfig.paged,
# docs/DESIGN.md §7)
# ---------------------------------------------------------------------------

# paper Table 2: each Mac Studio node is an M2 Ultra with 192 GB of
# unified memory — weights, KV cache and activations share one budget,
# which is exactly why the paper pre-allocates buffers (C1) and why the
# cache layout decides max concurrency
M2_ULTRA_MEM_BYTES = 192e9

# quantized weight-store levels (core/quant.py, docs/DESIGN.md §8) in
# preference order: least-lossy first
WEIGHT_QUANT_LEVELS = ("none", "int8", "int4")


def _itemsize(cfg) -> int:
    import numpy as _np
    return _np.dtype(getattr(cfg, "param_dtype", "bfloat16")).itemsize


def quant_matrix_bytes(k: int, n: int, *, itemsize: int,
                       quant: str = "none", block: int = 128,
                       lead: int = 1) -> float:
    """Stored bytes of ``lead`` stacked (k, n) weight matrices at a quant
    level — the analytic twin of ``core/quant.quantize``'s layout: int8
    keeps k rows of 1-byte values, int4 packs two per byte (``ceil(k/2)``
    rows), and both add one fp32 scale per ``block`` of the reduction
    axis per output column."""
    if quant == "none":
        return float(lead * k * n * itemsize)
    nb = -(-k // block)
    payload = (-(-k // 2) if quant == "int4" else k) * n
    return float(lead * (payload + nb * n * 4))


def _resolve_quant(cfg, quant, block):
    """Default quant level / block / kinds from the config's weight-store
    knobs (one resolver shared by every weight-bytes term)."""
    if quant is None:
        quant = getattr(cfg, "weight_quant", "none")
    block = block or getattr(cfg, "weight_quant_block", 128)
    kinds = tuple(getattr(cfg, "weight_quant_kinds",
                          ("attn", "mlp", "experts", "lm_head")))
    return quant, block, kinds


def _expert_layer_bytes(cfg, quant, block, kinds) -> float:
    """ONE layer's expert-stack bytes (the shardable part): the single
    source of the lead = E_padded x replication formula used by both the
    per-layer term and the per-node split."""
    eq = quant if "experts" in kinds else "none"
    p = _itemsize(cfg)
    d, f = cfg.d_model, cfg.d_ff
    lead = cfg.num_experts_padded * max(
        getattr(cfg, "expert_replication", 1), 1)
    return (2 * quant_matrix_bytes(d, f, itemsize=p, quant=eq, block=block,
                                   lead=lead)
            + quant_matrix_bytes(f, d, itemsize=p, quant=eq, block=block,
                                 lead=lead))


def weight_bytes_per_layer(cfg, *, quant: str | None = None,
                           block: int | None = None) -> float:
    """One decoder layer's stored weight bytes under the blockwise weight
    store — exact for the attention families (dense/moe/vlm/audio; the
    formula mirrors ``transformer.init_blocks`` leaf for leaf and is
    validated against ``jax.eval_shape`` of the constructed params in
    tests/test_perf_model.py, the same pattern as ``kv_bytes_per_token``).
    ``quant``/``block`` default to the config's ``weight_quant`` knobs;
    kinds follow ``cfg.weight_quant_kinds`` (router stays fp by
    default)."""
    if cfg.family in ("ssm", "hybrid"):
        raise ValueError(
            f"weight_bytes_per_layer models attention-family layers, not "
            f"{cfg.family!r}")
    quant, block, kinds = _resolve_quant(cfg, quant, block)
    p = _itemsize(cfg)
    d, f = cfg.d_model, cfg.d_ff
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    norm_elems = 2 * d if cfg.norm == "layernorm" else d
    total = 2 * norm_elems * p                     # ln1 + ln2
    aq = quant if "attn" in kinds else "none"
    total += quant_matrix_bytes(d, hq * hd, itemsize=p, quant=aq,
                                block=block)
    total += 2 * quant_matrix_bytes(d, hkv * hd, itemsize=p, quant=aq,
                                    block=block)
    total += quant_matrix_bytes(hq * hd, d, itemsize=p, quant=aq,
                                block=block)
    if cfg.qkv_bias:
        total += (hq + 2 * hkv) * hd * p
    if cfg.qk_norm:
        total += 2 * hd * p
    if cfg.is_moe:
        rq = quant if "router" in kinds else "none"
        total += quant_matrix_bytes(d, cfg.num_experts_padded, itemsize=p,
                                    quant=rq, block=block)
        total += _expert_layer_bytes(cfg, quant, block, kinds)
    else:
        mq = quant if "mlp" in kinds else "none"
        total += 2 * quant_matrix_bytes(d, f, itemsize=p, quant=mq,
                                        block=block)
        total += quant_matrix_bytes(f, d, itemsize=p, quant=mq, block=block)
    return total


def expert_weight_bytes(cfg, *, quant: str | None = None,
                        block: int | None = None) -> float:
    """All layers' expert-stack bytes — the shardable part of the model
    (every other weight is replicated per node under the decentralized
    schedule)."""
    if not cfg.is_moe:
        return 0.0
    quant, block, kinds = _resolve_quant(cfg, quant, block)
    return cfg.num_layers * _expert_layer_bytes(cfg, quant, block, kinds)


def model_weight_bytes(cfg, *, quant: str | None = None,
                       block: int | None = None) -> float:
    """Total stored weight bytes of the constructed params pytree:
    embedding (+ lm_head unless tied) + final norm + all layers.  The
    quantity ``engine.memory_stats()['weight_bytes']`` reports, exact
    against ``jax.eval_shape`` of ``quantize_params(model.init(...))``."""
    quant, block, kinds = _resolve_quant(cfg, quant, block)
    p = _itemsize(cfg)
    d = cfg.d_model
    total = cfg.vocab_padded * d * p               # embed (always fp)
    if not cfg.tie_embeddings:
        hq = quant if "lm_head" in kinds else "none"
        total += quant_matrix_bytes(d, cfg.vocab_padded, itemsize=p,
                                    quant=hq, block=block)
    total += (2 * d if cfg.norm == "layernorm" else d) * p   # final_norm
    return total + cfg.num_layers * weight_bytes_per_layer(
        cfg, quant=quant, block=block)


def per_node_weight_bytes(cfg, *, n_nodes: int = 1,
                          quant: str | None = None,
                          block: int | None = None) -> float:
    """Weight bytes resident on ONE of ``n_nodes`` expert-parallel nodes:
    the expert stack divides across nodes, everything else (attention,
    router, embeddings) is replicated — the decentralized schedule's
    placement (paper Fig. 7), which is what the Table-2 memory budget
    constrains."""
    ex = expert_weight_bytes(cfg, quant=quant, block=block)
    shared = model_weight_bytes(cfg, quant=quant, block=block) - ex
    return shared + ex / max(n_nodes, 1)


def fits_in_memory(cfg, *, n_nodes: int = 1, quant: str | None = None,
                   block: int | None = None,
                   budget: float = M2_ULTRA_MEM_BYTES,
                   kv_pool_bytes: float = 0.0) -> bool:
    """Does the model (at a quant level) plus a KV pool fit one node's
    unified-memory budget?  The weight-bytes term composed with the PR-4
    capacity term: weights are the dominant consumer and quantization the
    lever that decides hostability at all."""
    return per_node_weight_bytes(cfg, n_nodes=n_nodes, quant=quant,
                                 block=block) + kv_pool_bytes <= budget


def max_model_at_budget(cfg, *, n_nodes: int = 1,
                        budget: float = M2_ULTRA_MEM_BYTES,
                        kv_pool_bytes: float = 0.0,
                        block: int | None = None) -> dict:
    """Which quant levels let ``n_nodes`` budget-sized nodes host this
    model (leaving ``kv_pool_bytes`` for the cache)?  Returns per-level
    fits/bytes plus ``level`` — the least-lossy level that fits (None if
    even int4 does not): the answer to "what fits on N M2-Ultra nodes at
    which quant level"."""
    out = {"fits": {}, "per_node_bytes": {}, "level": None}
    for level in WEIGHT_QUANT_LEVELS:
        b = per_node_weight_bytes(cfg, n_nodes=n_nodes, quant=level,
                                  block=block)
        out["per_node_bytes"][level] = b
        out["fits"][level] = b + kv_pool_bytes <= budget
        if out["level"] is None and out["fits"][level]:
            out["level"] = level
    return out


def kv_bytes_per_token(cfg=None, *, n_layers: int = 0, num_kv_heads: int = 0,
                       head_dim: int = 0, precision: int = 2,
                       quantized: bool = False) -> float:
    """KV-cache bytes one token occupies across all layers (K and V).
    Pass a ModelConfig or the raw dims; ``quantized`` models the int8
    cache (1 byte/value + one fp32 scale per (token, head) for each of
    K and V)."""
    if cfg is not None:
        n_layers, num_kv_heads, head_dim = (cfg.num_layers, cfg.num_kv_heads,
                                            cfg.head_dim)
        quantized = getattr(cfg, "kv_cache_dtype", "") == "int8"
    per_value = 1 if quantized else precision
    per_tok = 2 * num_kv_heads * head_dim * per_value
    if quantized:
        per_tok += 2 * num_kv_heads * 4          # fp32 scales
    return float(n_layers * per_tok)


def paged_attention_read_bytes(cfg, *, lengths, page_size: int,
                               max_blocks: int) -> dict:
    """Per-decode-step attention K/V bytes READ, gather path vs Pallas
    kernel, for a batch whose rows hold ``lengths`` context tokens.

    The gather path (models/attention.attn_block_step_paged) materializes
    every row's full block-table reach — ``max_blocks * page_size`` slots
    per row regardless of how many hold tokens — while the kernel
    (kernels/paged_attn.py) walks only the pages a row's live length
    touches (beyond-length grid steps re-read the last live page, which
    Pallas elides).  Both read whole pages: that rounding is the page
    granularity, not a kernel artifact.  Returns per-step byte totals and
    their ratio — the virtual-cache traffic the kernel removes."""
    bpt = kv_bytes_per_token(cfg)
    gather = len(list(lengths)) * max_blocks * page_size * bpt
    kernel = sum(-(-(int(n) + 1) // page_size) * page_size
                 for n in lengths) * bpt
    return {"gather_bytes": float(gather), "kernel_bytes": float(kernel),
            "ratio": float(gather / kernel) if kernel else float("inf")}


def max_concurrent_requests(pool_bytes: float, bytes_per_token: float,
                            mean_context: int, *, page_size: int = 0,
                            slot_len: int = 0) -> int:
    """Memory-capacity term: how many requests a KV pool of ``pool_bytes``
    holds at once.

    The contiguous layout (``page_size=0``) reserves ``slot_len``
    (max_cache) token slots per admitted request regardless of use — the
    pre-PR-4 engine.  The paged layout rounds each request's real context
    up to whole pages only, so short requests stop paying for long ones'
    headroom; with ``page_size=1`` this is the information-theoretic bound
    pool_tokens / mean_context.  ``mean_context`` is prompt + generated
    tokens actually resident (the Table-2 budget divides by THIS, not by
    max_cache, once the cache is paged)."""
    if pool_bytes <= 0 or bytes_per_token <= 0:
        return 0
    pool_tokens = pool_bytes / bytes_per_token
    if page_size <= 0:
        per_req = max(slot_len, mean_context)
    else:
        per_req = -(-mean_context // page_size) * page_size
    return int(pool_tokens // max(per_req, 1))


def serving_capacity(cfg, *, pool_bytes: float, max_cache: int,
                     mean_context: int, page_size: int) -> dict:
    """Contiguous-vs-paged concurrency at EQUAL pool bytes (the ISSUE-4
    acceptance comparison): returns both bounds plus their ratio — the
    concurrency the paged layout buys from the same unified-memory
    budget."""
    bpt = kv_bytes_per_token(cfg)
    contiguous = max_concurrent_requests(pool_bytes, bpt, mean_context,
                                         slot_len=max_cache)
    paged = max_concurrent_requests(pool_bytes, bpt, mean_context,
                                    page_size=page_size)
    return {"bytes_per_token": bpt, "contiguous": contiguous,
            "paged": paged,
            "gain": paged / contiguous if contiguous else float("inf")}


def node_serving_capacity(cfg, *, n_nodes: int, max_cache: int,
                          mean_context: int, page_size: int,
                          quant: str | None = None,
                          budget: float = M2_ULTRA_MEM_BYTES) -> dict:
    """The weight-bytes term composed with the PR-4 KV-capacity term:
    on ``n_nodes`` budget-sized nodes, the quantized weight store takes
    its per-node share first and WHATEVER REMAINS is the KV pool —
    ``serving_capacity`` then converts that pool into concurrent-request
    bounds.  One call answers "what fits on N M2-Ultra nodes at which
    quant level, and how many requests does the leftover memory serve"
    (docs/DESIGN.md §8)."""
    wb = per_node_weight_bytes(cfg, n_nodes=n_nodes, quant=quant)
    pool = max(budget - wb, 0.0)
    out = serving_capacity(cfg, pool_bytes=pool, max_cache=max_cache,
                           mean_context=mean_context, page_size=page_size)
    out.update(weight_bytes_per_node=wb, kv_pool_bytes=pool,
               fits=wb <= budget,
               quant=quant if quant is not None
               else getattr(cfg, "weight_quant", "none"))
    return out


def prefix_hit_ttft(w: MoEWorkload, hw: HardwareProfile, n_nodes: int,
                    prompt_len: int, shared_len: int, chunk_len: int,
                    decode_rows: int = 0, page_size: int = 1,
                    num_experts: int = 16, top_k: int = 4) -> float:
    """Modelled TTFT of a prompt whose leading ``shared_len`` tokens hit
    the prefix cache (serving/paging.PrefixCache): only the page-aligned
    shared prefix is skipped (rounded DOWN to whole pages — partial tail
    sharing additionally recovers up to a page, but never the final
    prompt token, which is always recomputed to produce the first logit).
    ``shared_len=0`` reproduces ``chunked_prefill_ttft`` exactly."""
    shared = min((shared_len // max(page_size, 1)) * max(page_size, 1),
                 prompt_len - 1)
    remaining = max(prompt_len - shared, 1)
    return chunked_prefill_ttft(w, hw, n_nodes, remaining, chunk_len,
                                decode_rows, num_experts, top_k)


def cost_efficiency(throughput: float, n_nodes: int,
                    hw: HardwareProfile) -> float:
    """Table 5 metric: tokens/sec per USD of list-price hardware."""
    return throughput / (n_nodes * hw.price_per_node)


PAPER_TABLE5 = {
    # solution: (n_nodes, throughput tokens/s, price/node USD)
    "databricks-8xh100": (1, 112.5, 289_000.0),
    "ours-2xm2ultra": (2, 5.9, 6_599.0),
}


def paper_table5() -> dict[str, float]:
    return {k: tp / (n * price) for k, (n, tp, price) in PAPER_TABLE5.items()}


# ---------------------------------------------------------------------------
# per-kind collective-byte predictions (analysis rule R2)
# ---------------------------------------------------------------------------
# The paper's §5.2 measurement — expert communication time ≈ expert
# computation time, dominated by per-message latency — makes the BYTES each
# schedule moves a first-class invariant: a schedule regression (an extra
# gather, a fallback silently engaging) shows up as a collective-byte
# mismatch long before a wall-clock benchmark notices.  This predicts, per
# device and per forward pass, the bytes each HLO collective kind should
# move for one (batch, seq) block, mirroring core/expert_parallel.py's
# schedule bodies exactly (including their decode fallbacks).  The analysis
# CLI (repro.analysis R2) compares these numbers against
# launch/hlo.analyze()'s trip-multiplied per-kind actuals.


def predicted_collective_bytes(cfg, *, batch: int, seq: int,
                               n_exp_shards: int = 1,
                               n_batch_shards: int = 1,
                               itemsize: int | None = None,
                               n_moe_layers: int | None = None,
                               include_tp: bool = True) -> dict:
    """Expected per-device collective bytes by HLO kind for one forward of
    a (batch, seq) token block under ``cfg.expert_parallel``.

    Bytes are the collective's *operand* bytes (what launch/hlo.analyze
    bills), per device, summed over MoE layers.  Returns {} when there is
    no expert axis — a single-device serving program must contain no
    collectives at all, which R2 enforces with a floor instead of a
    tolerance.  Besides the expert schedule, ``include_tp`` adds the
    serve-mode tensor-parallel terms launch/sharding.params_pspec induces
    on the same "model" axis (vocab-sharded embedding psum, head-sharded
    attention-output psum, flat-sharded GQA k/v gathers); tiny aux pmeans
    (scalars) stay below any sensible floor and are omitted.
    """
    if n_exp_shards <= 1 or not getattr(cfg, "is_moe", False):
        return {}
    from repro.core import moe as moe_lib  # lazy: keep module import-light
    iz = itemsize if itemsize is not None else _itemsize(cfg)
    d = cfg.d_model
    k = cfg.experts_per_token
    e_pad = cfg.num_experts_padded
    L = n_moe_layers if n_moe_layers is not None else cfg.num_layers
    n = n_exp_shards
    # expert_parallel.moe_layer drops the batch axes when they don't divide
    bs = n_batch_shards if n_batch_shards >= 1 and batch % max(n_batch_shards, 1) == 0 else 1
    t = batch * seq
    t_bs = max(t // bs, 1)              # tokens per batch shard

    def decentralized():
        # one psum of the (t_loc, d) expert output per layer
        return {"all-reduce": float(L * t_bs * d * iz)}

    def centralized():
        if seq % n != 0:
            # decode fallback: psum + value-preserving ring permute
            nb = float(L * t_bs * d * iz)
            return {"all-reduce": nb, "collective-permute": nb}
        t_loc = t_bs // n
        # comm 1 gathers the activation block AND its bool token mask
        return {"all-gather": float(L * t_loc * (d * iz + 1)),
                "reduce-scatter": float(L * t_bs * d * iz)}

    def a2a(m: int = 1):
        if seq % n != 0:
            return decentralized()      # single-token decode fallback
        t_loc = t_bs // n
        if m > 1 and (t_loc % m != 0 or t_loc // m < 1):
            m = 1                       # a2a_pipelined -> plain a2a
        cap = moe_lib.round_capacity(max(t_loc // m, 1), k, e_pad,
                                     cfg.capacity_factor)
        e_local = e_pad // n
        # dispatch + combine all_to_all of (n, e_local*cap, d) per chunk
        return {"all-to-all": float(2 * L * m * n * e_local * cap * d * iz)}

    sched = getattr(cfg, "expert_parallel", "decentralized")
    if sched == "centralized":
        out = centralized()
    elif sched == "a2a":
        out = a2a()
    elif sched == "a2a_pipelined":
        out = a2a(max(getattr(cfg, "ep_microchunks", 1), 1))
    else:
        out = decentralized()

    if include_tp:
        def add(kind, nb):
            out[kind] = out.get(kind, 0.0) + float(nb)
        La = cfg.num_layers          # attention sits in every layer
        # vocab-sharded embedding table -> one psum of the (t_loc, d)
        # input activations per forward
        add("all-reduce", t_bs * d * iz)
        # head-sharded attention: per-layer psum of wo's partial outputs
        if cfg.num_heads % n == 0:
            add("all-reduce", La * t_bs * d * iz)
        # GQA k/v sharded on the flat head*dim axis: each device gathers
        # the new tokens' k and v before the (replicated) cache update
        kv_flat = cfg.num_kv_heads * cfg.head_dim
        if cfg.num_kv_heads % n != 0 and kv_flat % n == 0:
            add("all-gather", 2 * La * t_bs * (kv_flat // n) * iz)
    return out

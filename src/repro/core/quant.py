"""Blockwise int8/int4 quantized weight store (docs/DESIGN.md §8).

The paper's cost-efficiency argument hinges on fitting DBRX-class MoE
weights inside each node's unified-memory budget (Table 2); weight bytes —
not KV bytes — are the dominant consumer, and weight quantization is the
lever that decides which models a consumer node can host at all.  This
module makes quantized weights *first-class pytree leaves* so the rest of
the framework is layout-agnostic:

  * ``QuantTensor`` — a pytree-registered dataclass holding an int8 (or
    packed-int4) payload plus per-block fp32 scales over the reduction
    axis.  Payload and scales are **sibling leaves** of one container, so
    donation, ``lax.scan`` slicing of prestacked (L, ...) weights, ckpt
    flattening and shard_map in_specs all see two ordinary arrays that
    travel together (the same reason the int8 KV cache stores ``k_scale``
    beside ``k``).
  * ``quantize`` / ``dequantize`` — the ONE symmetric absmax numeric
    policy.  The reduction axis is always axis **-2** (every weight matmul
    in this framework contracts the second-to-last dim), split into
    ``block``-sized groups; each group stores one fp32 scale
    ``absmax / qmax``.  ``attention.quantize_kv`` wraps the same
    low-level ``absmax_quantize`` (axis -1, one block over ``hd``) so the
    repo has exactly one quantization numeric policy.
  * ``qdot`` — the single policy point every weight-consuming matmul goes
    through: raw arrays pass straight to ``jnp.einsum`` (bit-identical to
    the pre-refactor call sites); ``QuantTensor`` weights are dequantized
    on the fly.  Call sites never branch on the weight representation.
  * ``quantize_tree`` / ``quantize_params`` — the quantize-on-load
    pipeline (one-time preprocessing, exactly like the paper's prestacking
    script): walk a params tree and convert eligible weight kinds
    (``attn``, ``mlp``, ``experts``, ``lm_head`` by default — router and
    embedding stay fp) into ``QuantTensor`` leaves.

Int4 packs two values per byte along the reduction axis (element 2i in the
low nibble, 2i+1 in the high nibble), symmetric in [-7, 7]; the logical
reduction size rides in ``orig_dim`` so ragged dims round-trip exactly.
Expert shards ride the existing expert-parallel schedules unchanged: the
leading (L, E) axes of payload and scales shard identically, and
activations stay fp end to end.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

Array = jax.Array

# symmetric ranges: int8 uses the full [-127, 127]; int4 packs nibbles and
# stays in [-7, 7] so low/high nibbles sign-extend identically
QMAX = {8: 127, 4: 7}
LEVELS = ("none", "int8", "int4")
BITS = {"int8": 8, "int4": 4}

# weight kinds quantized by default (ModelConfig.weight_quant_kinds):
# router and embedding stay fp — the router's (D, E) matrix is tiny and its
# top-k is precision-sensitive; the embedding is consumed by row *gather*,
# not a matmul, so it never passes through the qdot policy point
DEFAULT_KINDS = ("attn", "mlp", "experts", "lm_head")

WEIGHT_NAMES = ("w_gate", "w_up", "w_down", "wq", "wk", "wv", "wo",
                "lm_head", "router", "embed")


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("data", "scale"),
                   meta_fields=("bits", "block", "orig_dim", "out_dtype"))
@dataclasses.dataclass(frozen=True)
class QuantTensor:
    """Blockwise-quantized weight: int8/int4 payload + per-block scales.

    ``data``:  int8 payload.  int8: the logical shape with the reduction
               axis (-2) unchanged; int4: two values packed per byte along
               axis -2 (``ceil(K/2)`` rows).
    ``scale``: fp32, logical shape with axis -2 replaced by the number of
               blocks ``ceil(K / block)``.
    ``bits`` / ``block``: quantization width and block size (static).
    ``orig_dim``: logical size K of the reduction axis (static) — int4
               packing and block padding are undone against it.
    ``out_dtype``: dtype string ``dequantize`` targets by default (the
               original weight dtype, so quantized and raw weights are
               interchangeable leaves).

    Leading axes (layer stack L, expert axis E) are ordinary batch axes of
    both leaves: ``lax.scan`` slices them in lockstep, shard_map in_specs
    written as rank-3 PartitionSpecs broadcast over both, and
    ``__getitem__`` gathers experts without touching the reduction axis.
    """
    data: Array
    scale: Array
    bits: int
    block: int
    orig_dim: int
    out_dtype: str

    @property
    def shape(self) -> tuple:
        """LOGICAL (unpacked) shape — call sites read e.g. E_local here."""
        s = list(self.data.shape)
        s[-2] = self.orig_dim
        return tuple(s)

    @property
    def ndim(self) -> int:
        return self.data.ndim

    @property
    def dtype(self):
        return jnp.dtype(self.out_dtype)

    def __getitem__(self, idx):
        """Leading-axis indexing/gather (e.g. gather_moe's selected-expert
        read): payload and scales index identically, the reduction axis is
        untouched, so the result is a valid QuantTensor."""
        return QuantTensor(self.data[idx], self.scale[idx], self.bits,
                           self.block, self.orig_dim, self.out_dtype)

    def dequantize(self, dtype=None) -> Array:
        return dequantize(self, dtype)


# ---------------------------------------------------------------------------
# the ONE numeric policy: per-block symmetric absmax quantization
# ---------------------------------------------------------------------------

def absmax_quantize(x: Array, *, bits: int = 8, block: int | None = None,
                    axis: int = -1) -> tuple[Array, Array]:
    """Per-block symmetric quantization along ``axis``.

    ``axis`` is split into ``ceil(K / block)`` groups of ``block`` (zero-
    padded); each group's scale is ``absmax / qmax`` and values round to
    ``round(x / max(scale, 1e-20))``.  Returns (q int8 with ``axis`` padded
    to a whole number of blocks, scale fp32 with ``axis`` replaced by the
    block count).  With ``block = K`` and ``axis = -1`` this is exactly the
    int8 KV-cache policy (one scale per (token, head) row), bit-identical
    to the pre-refactor ``attention.quantize_kv``.
    """
    axis = axis % x.ndim
    k = x.shape[axis]
    block = block or k
    nb = -(-k // block)
    xf = x.astype(jnp.float32)
    if nb * block != k:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, nb * block - k)
        xf = jnp.pad(xf, pad)
    xb = xf.reshape(xf.shape[:axis] + (nb, block) + xf.shape[axis + 1:])
    scale = jnp.max(jnp.abs(xb), axis=axis + 1) / QMAX[bits]
    q = jnp.round(xb / jnp.maximum(jnp.expand_dims(scale, axis + 1), 1e-20))
    return q.astype(jnp.int8).reshape(xf.shape), scale


def absmax_dequantize(q: Array, scale: Array, *, block: int, axis: int = -1,
                      dtype=jnp.float32) -> Array:
    """Inverse of ``absmax_quantize``: repeat each block's scale over its
    ``block`` values (truncated to the payload's extent) and multiply."""
    axis = axis % q.ndim
    s = jnp.repeat(scale, block, axis=axis)
    if s.shape[axis] != q.shape[axis]:
        s = jax.lax.slice_in_dim(s, 0, q.shape[axis], axis=axis)
    return (q.astype(jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# int4 nibble packing (two values per byte along the reduction axis)
# ---------------------------------------------------------------------------

def pack_int4(q: Array, axis: int = -2) -> Array:
    """int8 values in [-7, 7] -> packed int8, pairs (2i, 2i+1) along
    ``axis`` (low, high nibble).  Odd extents are zero-padded."""
    axis = axis % q.ndim
    k = q.shape[axis]
    if k % 2:
        pad = [(0, 0)] * q.ndim
        pad[axis] = (0, 1)
        q = jnp.pad(q, pad)
        k += 1
    pairs = q.reshape(q.shape[:axis] + (k // 2, 2) + q.shape[axis + 1:])
    lo = jax.lax.index_in_dim(pairs, 0, axis + 1, keepdims=False)
    hi = jax.lax.index_in_dim(pairs, 1, axis + 1, keepdims=False)
    lo_u = jax.lax.bitcast_convert_type(lo, jnp.uint8)
    hi_u = jax.lax.bitcast_convert_type(hi, jnp.uint8)
    packed = (lo_u & 0xF) | ((hi_u & 0xF) << 4)
    return jax.lax.bitcast_convert_type(packed, jnp.int8)


def unpack_int4(p: Array, axis: int = -2) -> Array:
    """Packed int8 -> int8 values, doubling ``axis`` (inverse of
    ``pack_int4``).  Pure shifts/compares — also runs inside Pallas."""
    axis = axis % p.ndim
    u = jax.lax.bitcast_convert_type(p, jnp.uint8)
    lo = (u & 0xF).astype(jnp.int8)
    hi = ((u >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    pairs = jnp.stack([lo, hi], axis=axis + 1)
    return pairs.reshape(p.shape[:axis] + (2 * p.shape[axis],)
                         + p.shape[axis + 1:])


# ---------------------------------------------------------------------------
# QuantTensor construction / materialization
# ---------------------------------------------------------------------------

def quantize(w: Array, level: str = "int8", *, block: int = 128
             ) -> QuantTensor:
    """Quantize a weight matrix (reduction axis -2) into a QuantTensor."""
    if level not in BITS:
        raise ValueError(f"unknown weight_quant level {level!r}; "
                         f"expected one of {LEVELS}")
    bits = BITS[level]
    if bits == 4 and block % 2:
        raise ValueError(f"int4 packing needs an even block, got {block}")
    k = w.shape[-2]
    q, scale = absmax_quantize(w, bits=bits, block=block, axis=-2)
    q = jax.lax.slice_in_dim(q, 0, k, axis=-2)   # drop block padding
    if bits == 4:
        q = pack_int4(q, axis=-2)
    return QuantTensor(q, scale, bits, block, k, str(w.dtype))


def dequantize(qt: QuantTensor, dtype=None) -> Array:
    """QuantTensor -> dense weight in ``dtype`` (default: the original
    weight dtype, so raw and quantized leaves are interchangeable)."""
    v = qt.data
    if qt.bits == 4:
        v = unpack_int4(v, axis=-2)
    v = jax.lax.slice_in_dim(v, 0, qt.orig_dim, axis=-2)
    return absmax_dequantize(v, qt.scale, block=qt.block, axis=-2,
                             dtype=dtype or jnp.dtype(qt.out_dtype))


def materialize(w, dtype=None):
    """Dequantize-or-identity: the helper for call sites that index or
    reshape weights rather than einsum them."""
    if isinstance(w, QuantTensor):
        return dequantize(w, dtype)
    return w


def qdot(eq: str, x: Array, w, *, preferred_element_type=None,
         weight_dtype=None) -> Array:
    """THE weight-matmul policy point: ``einsum(eq, x, w)`` where ``w`` is
    a raw array (bit-identical passthrough) or a QuantTensor (dequantized
    on the fly, to ``weight_dtype`` or its original dtype).  ``eq`` must
    contract ``w``'s axis -2 — the invariant the store quantizes along."""
    if isinstance(w, QuantTensor):
        w = dequantize(w, weight_dtype)
    elif weight_dtype is not None:
        w = w.astype(weight_dtype)
    if preferred_element_type is not None:
        return jnp.einsum(eq, x, w,
                          preferred_element_type=preferred_element_type)
    return jnp.einsum(eq, x, w)


# ---------------------------------------------------------------------------
# quantize-on-load: tree policy (the paper's one-time preprocessing step)
# ---------------------------------------------------------------------------

def classify_weight(names: list[str]) -> str | None:
    """Map a params-tree path to a weight kind, or None for leaves the
    store never touches (norms, biases, conv kernels, ssm state, ...)."""
    name = names[-1]
    if name not in WEIGHT_NAMES:
        return None
    if name == "embed":
        return "embed"
    if name == "lm_head":
        return "lm_head"
    if name == "router":
        return "router"
    if name in ("wq", "wk", "wv", "wo"):
        return "attn"
    # w_gate / w_up / w_down: experts when under the expert stack
    return "experts" if "experts" in names else "mlp"


def quantize_tree(params, level: str, *, block: int = 128,
                  kinds: tuple = DEFAULT_KINDS):
    """Convert eligible weight leaves of ``params`` to QuantTensor.

    ``level='none'`` is the identity (the raw tree round-trips through the
    store untouched); already-quantized leaves pass through, so the
    pipeline is idempotent.  Only >=2-D leaves whose path classifies into
    ``kinds`` are converted; ``embed`` is rejected even if requested (it
    is consumed by row gather, not a matmul — keep it fp)."""
    if level == "none":
        return params
    if "embed" in kinds:
        raise ValueError("the embedding is consumed by row gather, not a "
                         "qdot matmul — it must stay fp")

    def rule(path, leaf):
        if isinstance(leaf, QuantTensor) or getattr(leaf, "ndim", 0) < 2:
            return leaf
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        if classify_weight(names) in kinds:
            return quantize(leaf, level, block=block)
        return leaf

    return jax.tree_util.tree_map_with_path(
        rule, params, is_leaf=lambda x: isinstance(x, QuantTensor))


def quantize_params(params, cfg):
    """Apply ``cfg.weight_quant`` / ``weight_quant_block`` /
    ``weight_quant_kinds`` to a full params tree — the engine's
    quantize-on-load entry point."""
    return quantize_tree(params, getattr(cfg, "weight_quant", "none"),
                         block=getattr(cfg, "weight_quant_block", 128),
                         kinds=tuple(getattr(cfg, "weight_quant_kinds",
                                             DEFAULT_KINDS)))


def dequantize_tree(tree, dtype=None):
    """Materialize every QuantTensor leaf back to a dense array (inverse of
    ``quantize_tree`` up to quantization error).  Serving the result as raw
    fp params is the *fake-quant reference*: it holds exactly the values
    the quantized store dequantizes on the fly, so a quantized engine must
    be argmax-token-identical to it — the machinery-correctness gate that
    is robust where raw-fp token equality is not (int8 rounding shifts
    logits by ~1e-2, far above greedy tie gaps; see docs/DESIGN.md §8)."""
    return jax.tree.map(
        lambda a: dequantize(a, dtype) if isinstance(a, QuantTensor) else a,
        tree, is_leaf=lambda x: isinstance(x, QuantTensor))


def tree_bytes(tree) -> int:
    """Total payload bytes of a pytree (QuantTensor leaves count their int8
    payload + fp32 scales — the number ``engine.memory_stats`` reports and
    ``perf_model.model_weight_bytes`` models)."""
    return int(sum(a.size * jnp.dtype(a.dtype).itemsize
                   for a in jax.tree.leaves(tree)))

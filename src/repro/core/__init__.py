from repro.core import (  # noqa: F401
    dynamic_load, expert_parallel, moe, perf_model, prestack, router)

"""JAX version-compatibility shims (single policy point for the repo).

The codebase targets the modern ``jax.shard_map`` API (``check_vma=`` for
the replication/varying-manual-axes checker) and the modern
``AbstractMesh(axis_sizes, axis_names)`` constructor.  Installed JAX
releases differ:

* 0.4.x ships ``shard_map`` under ``jax.experimental.shard_map`` and calls
  the checker ``check_rep``;
* 0.4.x ``AbstractMesh`` takes a single tuple of ``(name, size)`` pairs.

Every call site in ``src/`` and ``tests/`` goes through this module rather
than feature-testing JAX locally, so a future version bump is a one-file
change.  Policy: support the modern spelling natively, translate for the
oldest JAX the container pins (0.4.37); never pin behaviour to a version
string — feature-detect the actual signature instead.
"""
from __future__ import annotations

import inspect
from typing import Any

import jax

if hasattr(jax, "shard_map"):                       # jax >= 0.6
    _shard_map = jax.shard_map
else:                                               # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_REP_KWARG = ("check_vma"
              if "check_vma" in inspect.signature(_shard_map).parameters
              else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the replication checker under its modern name.

    On JAX versions that predate the varying-manual-axes rename the checker
    is the legacy ``check_rep``, whose replication inference cannot handle
    ``lax.scan`` carries (it raises "Scan carry input and output got
    mismatched replication types ... as a temporary workaround pass
    check_rep=False").  Every layer stack in this repo runs its shard_maps
    under ``lax.scan``, so on those versions the checker is disabled
    wholesale; on modern JAX ``check_vma`` is passed through unchanged.
    """
    if _REP_KWARG == "check_rep":
        check_vma = False
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_REP_KWARG: check_vma})


def abstract_mesh(axis_sizes: tuple[int, ...],
                  axis_names: tuple[str, ...]) -> Any:
    """Construct ``jax.sharding.AbstractMesh`` on any supported JAX.

    Modern JAX: ``AbstractMesh(axis_sizes, axis_names)``.
    JAX 0.4.x:  ``AbstractMesh(((name, size), ...))``.
    """
    from jax.sharding import AbstractMesh

    pairs = tuple(zip(axis_names, axis_sizes))
    try:
        return AbstractMesh(pairs)
    except TypeError:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))

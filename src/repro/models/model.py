"""Public model API: build_model(cfg) -> Model with pure-functional entry
points used by the launcher, serving engine, tests and benchmarks.

  init(rng)                          -> params
  forward(params, batch, mesh)       -> (logits, aux_loss)      full sequence
  loss(params, batch, mesh)          -> (scalar, metrics)       training loss
  prefill(params, batch, cache, mesh)-> (logits_last, cache)
  decode_step(params, cache, batch, mesh) -> (logits, cache)    one token
  cache_specs(batch, cache_len)      -> ShapeDtypeStruct pytree

Routing-capture variants (device-side aux outputs, zero extra router
evaluations — the serving engine's hot loop consumes these so expert
statistics never require a host-side router replay):

  prefill_routed(params, batch, cache, mesh)
      -> (logits_last, cache, routing)   routing: (L, B*S, K) int32 | None
  decode_step_routed(params, cache, batch, mesh)
      -> (logits, cache, routing)        routing: (L, B, K) int32 | None

Both routed entry points honour an optional ``batch["token_mask"]``
((B, S) bool): False tokens are dead-routed past the MoE dispatch so they
consume no expert capacity (how the serving engine's batched prefill keeps
garbage/in-flight rows from perturbing real requests); their ``routing``
entries read E_pad.

Donation safety: ``prefill_routed`` / ``decode_step_routed`` update the
cache exclusively via ``dynamic_update_slice`` on a scan carry
(transformer._scan_stack_with_cache) — a caller that jits with the cache
in ``donate_argnums`` gets in-place aliasing and a zero-copy decode step
(tests/test_zero_copy.py).  ``lengths`` is a separate, never-donated
operand, preserving the engine's host-snapshot race fix (the host may
mutate its own lengths array after dispatch; the device sees the
snapshot).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.models import layers, transformer

Array = jax.Array


def sinusoidal_embedding(positions: Array, d: int) -> Array:
    """positions: (B, S) -> (B, S, d) fp32 sinusoidal table."""
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    init: Callable
    forward: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    cache_specs: Callable
    init_cache: Callable
    prefill_routed: Callable
    decode_step_routed: Callable
    forward_routed: Callable
    paged_cache_specs: Callable
    init_paged_cache: Callable


def _embed_inputs(cfg, params, batch) -> tuple[Array, Array, Array | None, Array]:
    """Returns (x (B,S,D), positions (B,S), mrope_pos or None, loss_mask (B,S))."""
    dt = cfg.dtype_jnp
    if cfg.family == "audio":
        x = batch["frame_embeds"].astype(dt)
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        mask = jnp.ones((b, s), jnp.float32)
    elif cfg.family == "vlm":
        tok = jnp.take(params["embed"],
                       jnp.clip(batch["tokens"], 0, cfg.vocab_size - 1), axis=0)
        x = jnp.concatenate([batch["patch_embeds"].astype(dt), tok.astype(dt)],
                            axis=1)
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        mask = (jnp.arange(s)[None] >= cfg.num_patch_tokens).astype(jnp.float32)
        mask = jnp.broadcast_to(mask, (b, s))
        return x, pos, batch.get("mrope_positions"), mask
    else:
        x = jnp.take(params["embed"],
                     jnp.clip(batch["tokens"], 0, cfg.vocab_size - 1),
                     axis=0).astype(dt)
        b, s = batch["tokens"].shape
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        mask = jnp.ones((b, s), jnp.float32)
    if cfg.positional == "sinusoidal":
        x = x + sinusoidal_embedding(pos, cfg.d_model).astype(dt)
    return x, pos, None, mask


def _lm_head(cfg, params, x: Array) -> Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return quant.qdot("bsd,dv->bsv", x, w, weight_dtype=x.dtype,
                      preferred_element_type=jnp.float32)


def build_model(cfg) -> Model:
    dt = cfg.dtype_jnp
    pdt = cfg.param_dtype_jnp

    # ---- init -----------------------------------------------------------
    def init(rng: Array) -> dict:
        k_emb, k_blocks, k_head = jax.random.split(rng, 3)
        params = {
            "embed": layers.embed_init(k_emb, cfg.vocab_padded, cfg.d_model, pdt),
            "blocks": transformer.init_blocks(cfg, k_blocks),
            "final_norm": layers.norm_init(cfg.norm, cfg.d_model, pdt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.dense_init(
                k_head, cfg.d_model, cfg.vocab_padded, pdt)
        return params

    # ---- full-sequence forward -------------------------------------------
    def forward(params, batch, mesh=None):
        x, pos, mrope, _ = _embed_inputs(cfg, params, batch)
        window = transformer.effective_window(cfg, x.shape[1])
        x, aux = transformer.forward_stack(cfg, mesh, params["blocks"], x, pos,
                                           window, mrope)
        x = layers.norm_apply(cfg.norm, params["final_norm"], x)
        return _lm_head(cfg, params, x), aux

    def _chunked_ce(params, x, labels, chunk: int = 512):
        """lm_head + CE one sequence chunk at a time — never materializes the
        full (B, S, V) logits (V can be 150k+)."""
        b, s, d = x.shape
        if s <= chunk or s % chunk != 0:
            return layers.softmax_cross_entropy(
                _lm_head(cfg, params, x), labels, cfg.vocab_size)
        nc = s // chunk
        xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
        lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

        def body(args):
            xx, ll = args
            return layers.softmax_cross_entropy(
                _lm_head(cfg, params, xx), ll, cfg.vocab_size)

        ce = jax.lax.map(jax.checkpoint(body), (xc, lc))     # (nc, B, chunk)
        return ce.transpose(1, 0, 2).reshape(b, s)

    def loss(params, batch, mesh=None):
        x, pos, mrope, mask = _embed_inputs(cfg, params, batch)
        window = transformer.effective_window(cfg, x.shape[1])
        x, aux = transformer.forward_stack(cfg, mesh, params["blocks"], x, pos,
                                           window, mrope)
        x = layers.norm_apply(cfg.norm, params["final_norm"], x)
        ce = _chunked_ce(params, x, batch["labels"])
        ce = jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    # ---- cache ------------------------------------------------------------
    def cache_specs(batch: int, cache_len: int):
        return transformer.stack_cache_spec(cfg, batch, cache_len, dt)

    def init_cache(batch: int, cache_len: int):
        return transformer.init_stack_cache(cfg, batch, cache_len, dt)

    # paged pool (docs/DESIGN.md §7): one (L, num_pages, page_size, Hkv,
    # hd) leaf per cache kind, shared across rows via per-row block tables
    # (batch["block_tables"] in forward_routed)
    def paged_cache_specs(num_pages: int, page_size: int):
        return transformer.paged_stack_cache_spec(cfg, num_pages, page_size,
                                                  dt)

    def init_paged_cache(num_pages: int, page_size: int):
        return transformer.init_paged_stack_cache(cfg, num_pages, page_size,
                                                  dt)

    # ---- prefill ------------------------------------------------------------
    def prefill_routed(params, batch, cache, mesh=None):
        x, pos, mrope, _ = _embed_inputs(cfg, params, batch)
        window = transformer.effective_window(cfg, x.shape[1])
        x, cache, routing = transformer.prefill_stack(
            cfg, mesh, params["blocks"], x, pos, cache, window, mrope,
            token_mask=batch.get("token_mask"))
        x = layers.norm_apply(cfg.norm, params["final_norm"], x[:, -1:])
        return _lm_head(cfg, params, x), cache, routing

    def prefill(params, batch, cache, mesh=None):
        logits, cache, _ = prefill_routed(params, batch, cache, mesh)
        return logits, cache

    # ---- decode -------------------------------------------------------------
    def decode_step_routed(params, cache, batch, mesh=None, context_len=None):
        tok = jnp.clip(batch["tokens"], 0, cfg.vocab_size - 1)
        x = jnp.take(params["embed"], tok, axis=0).astype(dt)
        lengths = batch["lengths"]
        if cfg.positional == "sinusoidal":
            x = x + sinusoidal_embedding(lengths[:, None], cfg.d_model).astype(dt)
        # windowing decision is made at the *logical* context length
        # (cache extent may already be clipped to the window => ring buffer)
        cache_len = _attn_cache_len(cfg, cache)
        window = (transformer.effective_window(cfg, context_len or cache_len)
                  if cache_len is not None else cfg.sliding_window)
        x, cache, routing = transformer.decode_stack(
            cfg, mesh, params["blocks"], x, lengths, cache, window,
            batch.get("mrope_positions"), token_mask=batch.get("token_mask"))
        x = layers.norm_apply(cfg.norm, params["final_norm"], x)
        return _lm_head(cfg, params, x), cache, routing

    def decode_step(params, cache, batch, mesh=None, context_len=None):
        logits, cache, _ = decode_step_routed(params, cache, batch, mesh,
                                              context_len)
        return logits, cache

    # ---- unified token-budget forward -----------------------------------
    def forward_routed(params, batch, cache, mesh=None, context_len=None,
                       paged_kernel=False):
        """Length-agnostic unified step: one (B, T) token block at arbitrary
        per-row cache offsets (docs/DESIGN.md §6).

        batch: {"tokens": (B, T) int32, "lengths": (B,) int32 cache offsets,
        "seg_lens": (B,) int32 valid-token counts, optional "token_mask",
        optional "block_tables"}.  Row b appends its first seg_lens[b]
        tokens at positions lengths[b]..lengths[b]+seg_lens[b]-1;
        T=1/seg_lens=1 is a decode step, seg_lens=T at lengths=0 is
        whole-prompt prefill, and per-row mixes are chunked-prefill /
        mixed prefill+decode batches.  The prefill/decode twins above
        remain as the two-program reference.

        With ``block_tables`` (B, NB) int32 the cache is the paged pool of
        ``init_paged_cache`` (docs/DESIGN.md §7): row b's logical block i
        lives on physical page block_tables[b, i], so rows sharing a
        prompt prefix alias the same pages and the pool is sized in pages,
        not max_batch x max_cache slots.  Block tables are host-scheduler
        state handed to the device like ``lengths`` — never donated.
        ``paged_kernel`` (static) attends through the Pallas block-table
        kernel instead of the virtual-cache gather (docs/DESIGN.md §11).

        Returns (logits (B, V) at each row's LAST VALID position, cache',
        routing (L, B*T, K) int32 | None).  The cache is updated via
        dynamic-slice writes on the layer-scan carry, so donating callers
        keep the zero-copy hot loop; ``lengths``/``seg_lens`` stay
        undonated host snapshots (same race rule as decode)."""
        if cfg.family not in ("dense", "moe"):
            raise NotImplementedError(
                f"forward_routed supports token-input attention families, "
                f"not {cfg.family!r}")
        tokens = batch["tokens"]
        lengths = batch["lengths"]
        seg_lens = batch["seg_lens"]
        b, t = tokens.shape
        tok = jnp.clip(tokens, 0, cfg.vocab_size - 1)
        x = jnp.take(params["embed"], tok, axis=0).astype(dt)
        positions = lengths[:, None] + jnp.arange(t, dtype=jnp.int32)[None]
        if cfg.positional == "sinusoidal":
            x = x + sinusoidal_embedding(positions, cfg.d_model).astype(dt)
        token_mask = batch.get("token_mask")
        if token_mask is None:
            token_mask = jnp.arange(t)[None] < seg_lens[:, None]
        block_tables = batch.get("block_tables")
        if block_tables is not None:
            # paged pool leaves are (L, P, page_size, ...): the per-row
            # cache extent is the block table's reach.  NB: this rounds
            # UP to whole pages — callers whose logical context is not
            # page-aligned should pass ``context_len`` so the windowing
            # decision (effective_window) matches the contiguous layout
            cache_len = block_tables.shape[1] * cache["k"].shape[2]
        else:
            cache_len = _attn_cache_len(cfg, cache)
        window = (transformer.effective_window(cfg, context_len or cache_len)
                  if cache_len is not None else cfg.sliding_window)
        x, cache, routing = transformer.unified_stack(
            cfg, mesh, params["blocks"], x, positions, lengths, seg_lens,
            cache, window, token_mask=token_mask, block_tables=block_tables,
            paged_kernel=paged_kernel and block_tables is not None)
        sel = jnp.clip(seg_lens - 1, 0, t - 1)
        x_sel = jnp.take_along_axis(x, sel[:, None, None], axis=1)  # (B,1,D)
        x_sel = layers.norm_apply(cfg.norm, params["final_norm"], x_sel)
        logits = _lm_head(cfg, params, x_sel)
        return logits[:, 0], cache, routing

    return Model(cfg, init, forward, loss, prefill, decode_step,
                 cache_specs, init_cache, prefill_routed, decode_step_routed,
                 forward_routed, paged_cache_specs, init_paged_cache)


def _attn_cache_len(cfg, cache) -> int | None:
    if cfg.family == "ssm":
        return None
    if cfg.family == "hybrid":
        return cache["attn"]["k"].shape[2]
    return cache["k"].shape[2]

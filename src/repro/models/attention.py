"""GQA attention with causal / sliding-window masking and a KV cache.

Three entry points share one core:
  * ``attend(q, k, v, ...)``       — masked SDPA, fp32 softmax
  * ``attn_forward(...)``          — train / prefill over a full sequence
  * ``attn_decode_step(...)``      — one new token against a cache

Cache layout (per layer): ``k``/``v`` of shape (B, S_max, H_kv, hd) plus a
shared per-sequence ``lengths`` (B,) kept at the model level.  For sliding
window attention the cache is a ring buffer of size ``window`` and positions
are stored modulo the window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import quant
from repro.models import layers

Array = jax.Array
NEG_INF = -1e9  # large-negative instead of -inf: keeps softmax NaN-free on fully-masked rows


def gqa_repeat(k: Array, q_heads: int) -> Array:
    """(..., H_kv, hd) -> (..., H_q, hd) by repeating each kv head."""
    kv_heads = k.shape[-2]
    if kv_heads == q_heads:
        return k
    rep = q_heads // kv_heads
    return jnp.repeat(k, rep, axis=-2)


def attend(q: Array, k: Array, v: Array, mask: Array, scale: float) -> Array:
    """q: (B,Sq,Hq,hd) k/v: (B,Sk,Hq,hd) mask: (B,1,Sq,Sk) bool -> (B,Sq,Hq,hd)."""
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# chunked (flash-style) attention — O(S) memory for long sequences
# ---------------------------------------------------------------------------

CHUNK_THRESHOLD = 2048   # switch to chunked attention at/above this S
# K/V are re-read once per q-chunk, so total k/v HBM traffic scales with
# S/Q_CHUNK: larger q-chunks amortize the K pass (measured 2x memory-term
# win on deepseek-67b prefill_32k going 512 -> 2048)
Q_CHUNK = 2048
K_CHUNK = 1024


def attend_chunked(q: Array, k: Array, v: Array, q_pos: Array, k_pos: Array,
                   window: int | None, scale: float,
                   q_chunk: int = Q_CHUNK, k_chunk: int = K_CHUNK) -> Array:
    """Online-softmax attention, never materializing (Sq, Sk) logits.

    q: (B,Sq,H,hd); k/v: (B,Sk,H,hd); q_pos: (B,Sq); k_pos: (B,Sk).
    Causal (k_pos <= q_pos) with optional sliding ``window``.  This is the
    pure-JAX oracle of the Pallas flash kernel (kernels/flash_attn.py) and
    the long-sequence path used by train/prefill.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    qc = min(q_chunk, sq)
    kc = min(k_chunk, sk)
    nq, nk = -(-sq // qc), -(-sk // kc)
    # pad to chunk multiples (positions padded with -1 / huge so masks kill them)
    qp = jnp.pad(q, ((0, 0), (0, nq * qc - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kc - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kc - sk), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos, ((0, 0), (0, nq * qc - sq)), constant_values=-1)
    kpos = jnp.pad(k_pos, ((0, 0), (0, nk * kc - sk)),
                   constant_values=2**30)

    qp = qp.reshape(b, nq, qc, h, hd).transpose(1, 0, 2, 3, 4)
    qpos_c = qpos.reshape(b, nq, qc).transpose(1, 0, 2)
    # pre-transpose k/v ONCE into MXU-operand layout — doing it inside the
    # q-loop re-transposes every k-chunk nq times (measured 50% of prefill
    # HBM traffic on deepseek-67b before this hoist)
    kp = kp.reshape(b, nk, kc, h, hd).transpose(1, 0, 3, 4, 2)  # (nk,B,H,hd,kc)
    vp = vp.reshape(b, nk, kc, h, hd).transpose(1, 0, 3, 2, 4)  # (nk,B,H,kc,hd)
    kpos_c = kpos.reshape(b, nk, kc).transpose(1, 0, 2)

    def q_block(args):
        qb, qpb = args                       # (B,qc,H,hd), (B,qc)
        qbt = qb.transpose(0, 2, 1, 3)       # (B,H,qc,hd) once per q-chunk

        def k_step(carry, kargs):
            acc, m, l = carry
            kb, vb, kpb = kargs              # (B,H,hd,kc), (B,H,kc,hd), (B,kc)
            logit = jnp.einsum("bhqd,bhdk->bhqk", qbt, kb,
                               preferred_element_type=jnp.float32) * scale
            msk = kpb[:, None, None, :] <= qpb[:, None, :, None]
            if window is not None:
                msk = msk & (kpb[:, None, None, :]
                             > qpb[:, None, :, None] - window)
            logit = jnp.where(msk, logit, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logit, axis=-1))
            p = jnp.exp(logit - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, h, qc, hd), jnp.float32)
        m0 = jnp.full((b, h, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, qc), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(k_step, (acc0, m0, l0),
                                      (kp, vp, kpos_c))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 2, 1, 3).astype(q.dtype)   # (B,qc,H,hd)

    # checkpoint each q-block: the inner k-scan's per-step residuals
    # (logits/probs stacks of shape (nq, nk, B, H, qc, kc)) are recomputed
    # in the backward instead of being written to HBM — the flash-attention
    # backward strategy expressed in pure JAX
    out = jax.lax.map(jax.checkpoint(q_block), (qp, qpos_c))  # (nq,B,qc,H,hd)
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nq * qc, h, hd)
    return out[:, :sq]


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def attn_init(key: Array, cfg, dtype) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], d, hq * hd, dtype),
        "wk": layers.dense_init(ks[1], d, hkv * hd, dtype),
        "wv": layers.dense_init(ks[2], d, hkv * hd, dtype),
        "wo": layers.dense_init(ks[3], hq * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(p: dict, cfg, x: Array, positions, mrope_positions=None,
                 mesh=None):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = quant.qdot("bsd,de->bse", x, p["wq"])
    k = quant.qdot("bsd,de->bse", x, p["wk"])
    v = quant.qdot("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    # pin the head layout at the reshape: serve-mode wk/wv shard the
    # flattened Hkv*hd dim, and letting GSPMD keep a mid-head split through
    # the per-head norm/rope below miscompiles on jaxlib 0.4.x CPU SPMD
    # (head_constrain replicates heads whenever H % tp != 0)
    q = head_constrain(mesh, q.reshape(b, s, hq, hd))
    k = head_constrain(mesh, k.reshape(b, s, hkv, hd))
    v = head_constrain(mesh, v.reshape(b, s, hkv, hd))
    if cfg.qk_norm:
        q = layers.rms_norm(p["q_norm"], q)
        k = layers.rms_norm(p["k_norm"], k)
    if cfg.mrope and mrope_positions is not None:
        q = layers.apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        k = layers.apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.use_rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def head_constrain(mesh, t: Array) -> Array:
    """Pin (B, S, H, hd) activations to head sharding over the 'model' axis —
    forces GSPMD into head-parallel attention (logits (B, H/tp, Sq, Sk) per
    device) instead of keeping sequence sharding through the softmax.

    When the head count does not divide the axis the heads are pinned to
    *replicated* instead of left to GSPMD: the propagated layout would split
    single heads mid-``hd`` (serve-mode wk/wv shard the flattened Hkv*hd
    dim), which is never a layout we want — and the jaxlib 0.4.x CPU SPMD
    partitioner miscompiles per-head norm/rope over such a split."""
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return t
    if t.ndim != 4:
        return t
    from jax.sharding import NamedSharding, PartitionSpec as P
    batch_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    nb = 1
    for a in batch_axes:
        nb *= mesh.shape[a]
    ba = batch_axes if (nb and t.shape[0] % nb == 0) else ()
    head = "model" if t.shape[2] % mesh.shape["model"] == 0 else None
    return jax.lax.with_sharding_constraint(
        t, NamedSharding(mesh, P(ba, None, head, None)))


def attn_forward(p: dict, cfg, x: Array, positions: Array, window: int | None,
                 mrope_positions: Array | None = None, mesh=None) -> Array:
    """x: (B, S, D); positions: (B, S) int32. Returns (B, S, D)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions, mrope_positions, mesh)
    k = gqa_repeat(k, cfg.num_heads)
    v = gqa_repeat(v, cfg.num_heads)
    # q is already head-pinned inside _project_qkv; k/v changed head count
    k = head_constrain(mesh, k)
    v = head_constrain(mesh, v)
    if getattr(cfg, "use_flash_kernel", False):
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, window=window)
        out = out.transpose(0, 2, 1, 3)
    elif s >= CHUNK_THRESHOLD:
        out = attend_chunked(q, k, v, positions, positions, window,
                             cfg.head_dim ** -0.5)
    else:
        qp = positions[:, None, :, None]  # (B,1,Sq,1)
        kp = positions[:, None, None, :]  # (B,1,1,Sk)
        mask = kp <= qp
        if window is not None:
            mask = mask & (kp > qp - window)
        out = attend(q, k, v, mask, cfg.head_dim ** -0.5)
    out = out.reshape(b, s, cfg.num_heads * cfg.head_dim)
    return quant.qdot("bse,ed->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# cached decode
# ---------------------------------------------------------------------------

def kv_quantized(cfg) -> bool:
    return getattr(cfg, "kv_cache_dtype", "") == "int8"


def init_layer_cache(cfg, batch: int, cache_len: int, dtype) -> dict:
    shape = (batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
    if kv_quantized(cfg):
        sshape = shape[:-1] + (1,)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v_scale": jnp.zeros(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def layer_cache_spec(cfg, batch: int, cache_len: int, dtype):
    shape = (batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
    if kv_quantized(cfg):
        sshape = shape[:-1] + (1,)
        return {"k": jax.ShapeDtypeStruct(shape, jnp.int8),
                "v": jax.ShapeDtypeStruct(shape, jnp.int8),
                "k_scale": jax.ShapeDtypeStruct(sshape, jnp.float32),
                "v_scale": jax.ShapeDtypeStruct(sshape, jnp.float32)}
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def quantize_kv(x: Array) -> tuple[Array, Array]:
    """Per-(token, head) symmetric int8: x (..., hd) -> (int8, fp32 scale).

    Thin wrapper over ``core/quant.absmax_quantize`` — ONE quantization
    numeric policy repo-wide (docs/DESIGN.md §8): a single block spanning
    the whole ``hd`` axis reproduces the original per-row absmax/127
    quantizer bit for bit (the block count is 1, so the scale keeps its
    (..., 1) keepdims shape)."""
    return quant.absmax_quantize(x, bits=8, block=x.shape[-1], axis=-1)


def dequantize_kv(q: Array, scale: Array, dtype) -> Array:
    """Inverse wrapper: one block over ``hd`` makes the per-block repeat a
    plain broadcast — bit-identical to the pre-refactor ``q * scale``."""
    return quant.absmax_dequantize(q, scale, block=q.shape[-1], axis=-1,
                                   dtype=dtype)


def _update_cache(cache_kv: Array, new_kv: Array, lengths: Array, ring: bool) -> Array:
    """Insert new_kv (B, 1, Hkv, hd) at per-sequence slot lengths (B,)."""
    cache_len = cache_kv.shape[1]
    slot = lengths % cache_len if ring else lengths

    def upd(c, n, s):
        return jax.lax.dynamic_update_slice(c, n, (s, 0, 0))

    return jax.vmap(upd)(cache_kv, new_kv, slot)


def _attend_grouped_block(cfg, q: Array, k_cache: Array, v_cache: Array,
                          mask: Array) -> Array:
    """Grouped-GQA attention of a (B, Tq) query block over the cache WITHOUT
    materializing ``gqa_repeat``: repeating Hkv cache heads to Hq reads (and,
    in the lowered HLO, copies) the entire KV cache G=Hq/Hkv times per layer
    per step — it was the residual full-cache-sized copy in the decode
    program after buffer donation.  Indexing kv heads per q-head group keeps
    the cache read exactly once (same trick as the CP-decode shard body and
    any TPU flash decode kernel).

    q: (B,Tq,Hq,hd); k_cache/v_cache: (B,S,Hkv,hd); mask: (B,Tq,S) bool.
    Returns (B,Tq,Hq,hd).  Tq=1 is the decode step; Tq>1 is the unified
    chunked-prefill / mixed-batch step (attn_block_step)."""
    hkv = k_cache.shape[2]
    g = cfg.num_heads // hkv
    scale = cfg.head_dim ** -0.5
    tq = q.shape[1]
    qg = q.reshape(q.shape[0], tq, hkv, g, q.shape[-1])      # (B,Tq,Hkv,G,hd)
    logits = jnp.einsum("bqhgd,bshd->bhgqs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    mask5 = mask[:, None, None, :, :]                        # (B,1,1,Tq,S)
    logits = jnp.where(mask5, logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgqs,bshd->bhgqd", probs.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    b, _, _, _, hd = out.shape
    out = out.astype(q.dtype).transpose(0, 3, 1, 2, 4)       # (B,Tq,Hkv,G,hd)
    return out.reshape(b, tq, hkv * g, hd)


def _attend_grouped_decode(cfg, q: Array, k_cache: Array, v_cache: Array,
                           mask: Array) -> Array:
    """Single-step (Tq=1) grouped-GQA attention; mask: (B,S) bool."""
    return _attend_grouped_block(cfg, q, k_cache, v_cache, mask[:, None, :])


def attn_decode_step(p: dict, cfg, cache: dict, x: Array, lengths: Array,
                     window: int | None,
                     mrope_positions: Array | None = None,
                     mesh=None) -> tuple[Array, dict]:
    """x: (B, 1, D); lengths: (B,) tokens already in cache. Returns (B,1,D), cache'."""
    b = x.shape[0]
    cache_len = cache["k"].shape[1]
    ring = window is not None and cache_len == window
    q, k_new, v_new = _project_qkv(p, cfg, x, lengths[:, None], mrope_positions,
                                   mesh)
    if kv_quantized(cfg):
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        new_cache = {
            "k": _update_cache(cache["k"], kq, lengths, ring),
            "v": _update_cache(cache["v"], vq, lengths, ring),
            "k_scale": _update_cache(cache["k_scale"], ks, lengths, ring),
            "v_scale": _update_cache(cache["v_scale"], vs, lengths, ring),
        }
        k_cache = dequantize_kv(new_cache["k"], new_cache["k_scale"], x.dtype)
        v_cache = dequantize_kv(new_cache["v"], new_cache["v_scale"], x.dtype)
    else:
        k_cache = _update_cache(cache["k"], k_new, lengths, ring)
        v_cache = _update_cache(cache["v"], v_new, lengths, ring)
        new_cache = {"k": k_cache, "v": v_cache}

    idx = jnp.arange(cache_len)[None, :]  # (1, S)
    if ring:
        # slot i holds absolute position: valid iff that position is within
        # the last `window` tokens of [0, lengths].
        n_valid = jnp.minimum(lengths[:, None] + 1, cache_len)
        # with ring writes, every slot < n_valid is a live position
        mask = idx < n_valid
    else:
        mask = idx <= lengths[:, None]
        if window is not None:
            mask = mask & (idx > lengths[:, None] - window)
    out = _attend_grouped_decode(cfg, q, k_cache, v_cache, mask)
    out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim)
    out = quant.qdot("bse,ed->bsd", out, p["wo"])
    return out, new_cache


def _update_cache_block(cache_kv: Array, new_kv: Array, lengths: Array,
                        seg_lens: Array, ring: bool) -> Array:
    """Insert new_kv (B, T, Hkv, hd) at per-row offsets ``lengths`` (B,),
    keeping only each row's first ``seg_lens[b]`` tokens — the block
    generalization of ``_update_cache``'s single-slot write.

    Non-ring path: a per-row read-modify-write of one T-sized block via
    ``dynamic_slice`` + ``dynamic_update_slice`` (donation-friendly: the
    only cache traffic is the T-block, never a full-cache copy).  The slice
    start is clamped to ``S - T`` so rows whose offset sits near the cache
    end never smear earlier slots; the in-block merge keeps the original
    value everywhere the (clamped) window does not hold a valid new token.

    Ring path (cache_len == window): slots wrap, so a masked per-token
    scatter writes position p at slot p % S and drops invalid tokens via an
    out-of-bounds sentinel index."""
    b, t = new_kv.shape[:2]
    cache_len = cache_kv.shape[1]
    if t > cache_len:
        raise ValueError(f"block length {t} exceeds cache length {cache_len}")
    new_kv = new_kv.astype(cache_kv.dtype)
    if ring:
        slots = lengths[:, None] + jnp.arange(t)[None, :]
        valid = jnp.arange(t)[None, :] < seg_lens[:, None]
        slots = jnp.where(valid, slots % cache_len, cache_len)  # OOB -> drop
        return jax.vmap(lambda c, n, s: c.at[s].set(n, mode="drop"))(
            cache_kv, new_kv, slots)

    def upd(c_row, n_row, off, sl):
        s0 = jnp.clip(off, 0, cache_len - t)
        old = jax.lax.dynamic_slice_in_dim(c_row, s0, t, axis=0)
        ci = s0 + jnp.arange(t) - off            # index into the new block
        ok = (ci >= 0) & (ci < sl)
        new = jnp.take(n_row, jnp.clip(ci, 0, t - 1), axis=0)
        blk = jnp.where(ok.reshape((t,) + (1,) * (n_row.ndim - 1)), new, old)
        return jax.lax.dynamic_update_slice_in_dim(c_row, blk, s0, axis=0)

    return jax.vmap(upd)(cache_kv, new_kv, lengths, seg_lens)


def block_slot_positions(lengths: Array, seg_lens: Array, cache_len: int,
                         ring: bool) -> Array:
    """Absolute position held by each cache slot after a block write.

    Non-ring caches store position p at slot p, so the map is just the slot
    index.  Ring caches store p at slot p % S; under the write invariant the
    slot holds the *largest* position <= hi = lengths + seg_lens - 1 congruent
    to it, and slots whose implied position is negative were never written.
    Returns (B, S) int32 (negative = slot not yet written)."""
    sidx = jnp.arange(cache_len, dtype=jnp.int32)[None, :]
    if not ring:
        return jnp.broadcast_to(sidx, (lengths.shape[0], cache_len))
    hi = (lengths + seg_lens - 1)[:, None]
    return hi - ((hi - sidx) % cache_len)


def attn_block_step(p: dict, cfg, cache: dict, x: Array, positions: Array,
                    lengths: Array, seg_lens: Array, window: int | None,
                    mrope_positions: Array | None = None,
                    mesh=None) -> tuple[Array, dict]:
    """Unified length-agnostic cached attention over a (B, T) token block.

    Each row b holds ``seg_lens[b]`` valid tokens (0..T) that continue its
    sequence at cache offset ``lengths[b]`` — T=1 with seg_lens=1 is a
    decode step, seg_lens=T at lengths=0 is whole-prompt prefill, and any
    mix of per-row values is a chunked-prefill / mixed prefill+decode batch
    (docs/DESIGN.md §6).  Position-offset causal masking makes token t of
    row b (absolute position ``positions[b, t]``) attend exactly the cache
    slots holding positions <= its own (and > pos - window under SWA);
    invalid tokens (t >= seg_lens[b]) get a fully-masked row, a dropped
    cache write, and garbage output the caller must ignore (the MoE layer
    dead-routes them via token_mask).

    x: (B, T, D); positions: (B, T) int32 absolute; lengths/seg_lens: (B,).
    Returns ((B, T, D), cache')."""
    b, t, _ = x.shape
    cache_len = cache["k"].shape[1]
    ring = window is not None and cache_len == window
    if ring and t > 1:
        # a multi-token chunk written into a wrapped ring BEFORE attention
        # overwrites slots whose old positions are still inside earlier
        # chunk tokens' windows — those keys would be silently lost.  Ring
        # caches therefore only take width-1 blocks (== the decode step);
        # the engine falls back to the reference path for ring-cache archs.
        raise ValueError(
            f"ring KV cache (window == cache_len == {cache_len}) supports "
            f"only width-1 blocks, got T={t}")
    q, k_new, v_new = _project_qkv(p, cfg, x, positions, mrope_positions,
                                   mesh)
    if kv_quantized(cfg):
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        new_cache = {
            kk: _update_cache_block(cache[kk], nn, lengths, seg_lens, ring)
            for kk, nn in (("k", kq), ("v", vq),
                           ("k_scale", ks), ("v_scale", vs))
        }
        k_cache = dequantize_kv(new_cache["k"], new_cache["k_scale"], x.dtype)
        v_cache = dequantize_kv(new_cache["v"], new_cache["v_scale"], x.dtype)
    else:
        k_cache = _update_cache_block(cache["k"], k_new, lengths, seg_lens,
                                      ring)
        v_cache = _update_cache_block(cache["v"], v_new, lengths, seg_lens,
                                      ring)
        new_cache = {"k": k_cache, "v": v_cache}

    slot_pos = block_slot_positions(lengths, seg_lens, cache_len, ring)
    valid = jnp.arange(t)[None, :] < seg_lens[:, None]
    qp = jnp.where(valid, positions, -1)                     # (B, T)
    mask = (slot_pos[:, None, :] >= 0) \
        & (slot_pos[:, None, :] <= qp[:, :, None])           # (B, T, S)
    if window is not None:
        mask = mask & (slot_pos[:, None, :] > qp[:, :, None] - window)
    out = _attend_grouped_block(cfg, q, k_cache, v_cache, mask)
    out = out.reshape(b, t, cfg.num_heads * cfg.head_dim)
    return quant.qdot("bse,ed->bsd", out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# paged KV cache: page pool + block-table attention (docs/DESIGN.md §7)
# ---------------------------------------------------------------------------

def paged_layer_cache_spec(cfg, num_pages: int, page_size: int, dtype):
    """Per-layer paged pool: ``(num_pages, page_size, Hkv, hd)``.  Unlike the
    contiguous layout there is no batch dimension — rows map logical blocks
    to physical pages through a per-row block table, so pool bytes buy
    tokens wherever they are needed instead of ``max_cache`` slots per
    admitted request."""
    shape = (num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
    if kv_quantized(cfg):
        sshape = shape[:-1] + (1,)
        return {"k": jax.ShapeDtypeStruct(shape, jnp.int8),
                "v": jax.ShapeDtypeStruct(shape, jnp.int8),
                "k_scale": jax.ShapeDtypeStruct(sshape, jnp.float32),
                "v_scale": jax.ShapeDtypeStruct(sshape, jnp.float32)}
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def _paged_scatter(pool: Array, new: Array, page: Array, slot: Array) -> Array:
    """Write new (B, T, ...) rows at (page, slot) pairs (B, T) into pool
    (P, ps, ...).  Out-of-range page ids drop the write (invalid tokens are
    routed to the ``num_pages`` sentinel).  An in-place scatter on the scan
    carry, so a donating caller keeps the zero-copy hot loop."""
    return pool.at[page, slot].set(new.astype(pool.dtype), mode="drop")


def attn_block_step_paged(p: dict, cfg, cache: dict, x: Array,
                          positions: Array, lengths: Array, seg_lens: Array,
                          block_tables: Array, window: int | None,
                          mrope_positions: Array | None = None,
                          mesh=None, use_kernel: bool = False
                          ) -> tuple[Array, dict]:
    """``attn_block_step`` over a paged KV cache.

    cache: pool leaves ``(num_pages, page_size, Hkv, hd)`` shared by every
    row; ``block_tables`` (B, NB) int32 maps row b's logical block i (cache
    positions [i*page_size, (i+1)*page_size)) to a physical page.  Rows
    sharing a prompt prefix point their leading entries at the same pages
    (serving/paging.PrefixCache), which is exact: causal attention makes a
    prefix's K/V a pure function of the prefix tokens.

    Token j of row b (absolute position ``positions[b, j]``) writes its
    K/V at page ``block_tables[b, pos // ps]`` slot ``pos % ps`` — an
    in-place scatter on the scan-carry pool (invalid tokens drop via an
    out-of-range page sentinel, exactly like the ring path of
    ``_update_cache_block``).  Attention then gathers each row's pages
    into a (B, NB*ps, Hkv, hd) virtual cache whose slot s holds absolute
    position s, so the position-offset causal mask of the contiguous path
    applies unchanged (the gather is the pure-JAX form of a paged-attention
    kernel's block-table indirection; it reads at most the same bytes the
    contiguous layout's full-cache attention read).  With
    ``use_kernel=True`` the Pallas kernel (kernels/paged_attn.py) replaces
    the gather: it walks the block table page by page in VMEM, so the
    virtual cache is never materialized and attention bytes scale with
    ``lengths`` instead of pool size (docs/DESIGN.md §11).  The kernel
    path requires the unified scheduler's position contract
    ``positions[b, j] == lengths[b] + j``, which ``forward_routed``
    guarantees.  Ring caches (sliding window == cache length) are never
    paged — the engine keeps the reference path for those archs — but
    plain position windows (the long-context SWA variant) mask exactly as
    in ``attn_block_step``.

    x: (B, T, D); positions: (B, T) absolute; lengths/seg_lens: (B,).
    Returns ((B, T, D), cache')."""
    b, t, _ = x.shape
    num_pages, page_size = cache["k"].shape[:2]
    nb = block_tables.shape[1]
    q, k_new, v_new = _project_qkv(p, cfg, x, positions, mrope_positions,
                                   mesh)
    valid = jnp.arange(t)[None, :] < seg_lens[:, None]          # (B, T)
    blk = positions // page_size
    page = jnp.take_along_axis(block_tables, jnp.clip(blk, 0, nb - 1),
                               axis=1)
    # invalid tokens and positions beyond the table drop their write
    page = jnp.where(valid & (blk < nb), page, num_pages)
    slot = positions % page_size

    if kv_quantized(cfg):
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        new_cache = {
            kk: _paged_scatter(cache[kk], nn, page, slot)
            for kk, nn in (("k", kq), ("v", vq),
                           ("k_scale", ks), ("v_scale", vs))
        }
    else:
        new_cache = {"k": _paged_scatter(cache["k"], k_new, page, slot),
                     "v": _paged_scatter(cache["v"], v_new, page, slot)}

    if use_kernel:
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.paged_attention(q, new_cache, block_tables,
                                         lengths, seg_lens, window=window)
        out = out.reshape(b, t, cfg.num_heads * cfg.head_dim)
        return quant.qdot("bse,ed->bsd", out, p["wo"]), new_cache

    bt = jnp.clip(block_tables, 0, num_pages - 1)

    def gather(pool):
        pages = jnp.take(pool, bt, axis=0)          # (B, NB, ps, Hkv, ·)
        return pages.reshape((b, nb * page_size) + pool.shape[2:])

    # virtual slot s holds absolute position s: the linear-cache mask
    slot_pos = jnp.arange(nb * page_size, dtype=jnp.int32)[None, None, :]
    qp = jnp.where(valid, positions, -1)[:, :, None]            # (B, T, 1)
    mask = slot_pos <= qp                                       # (B, T, S)
    if window is not None:
        mask = mask & (slot_pos > qp - window)

    if kv_quantized(cfg):
        # dequantize only the slots some token attends (the per-row union
        # of the mask): zeroing the int8 payload elsewhere first is
        # bit-exact for every attended slot — excluded slots' logits are
        # overwritten with NEG_INF regardless of their K/V content — and
        # spares the multiply over the pool-sized dead tail
        attended = jnp.any(mask, axis=1)[:, :, None, None]      # (B, S, 1, 1)
        dq = lambda kk: dequantize_kv(
            jnp.where(attended, gather(new_cache[kk]), 0),
            gather(new_cache[kk + "_scale"]), x.dtype)
        k_cache, v_cache = dq("k"), dq("v")
    else:
        k_cache, v_cache = gather(new_cache["k"]), gather(new_cache["v"])

    out = _attend_grouped_block(cfg, q, k_cache, v_cache, mask)
    out = out.reshape(b, t, cfg.num_heads * cfg.head_dim)
    return quant.qdot("bse,ed->bsd", out, p["wo"]), new_cache


def attn_decode_step_cp(p: dict, cfg, cache: dict, x: Array, lengths: Array,
                        window: int | None, mesh,
                        mrope_positions: Array | None = None
                        ) -> tuple[Array, dict]:
    """Decode-time context parallelism: the KV cache is sequence-sharded over
    the "model" axis; each shard attends its local chunk and the partial
    (acc, m, l) online-softmax stats are merged with a pmax + two psums of
    (B, Hq, 1, ·) — a few hundred KB instead of gathering the full cache.

    This is the paper's decentralized one-all-reduce design (§4.3) applied
    to attention: replicate the small operands (q, new k/v), shard the big
    state, reduce once.  Projections (wq..wo) run OUTSIDE under GSPMD, so
    head-sharded weights keep working unchanged.
    """
    from jax.sharding import PartitionSpec as P

    b = x.shape[0]
    cache_len = cache["k"].shape[1]
    tp = mesh.shape["model"]
    ring = window is not None and cache_len == window
    q, k_new, v_new = _project_qkv(p, cfg, x, lengths[:, None], mrope_positions,
                                   mesh)
    kv_q = kv_quantized(cfg)
    if kv_q:
        kq, ksc = quantize_kv(k_new)
        vq, vsc = quantize_kv(v_new)
        new_tree = {"k": kq, "v": vq, "k_scale": ksc, "v_scale": vsc}
    else:
        new_tree = {"k": k_new, "v": v_new}
    cache_tree = {kk: cache[kk] for kk in new_tree}

    batch_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    nb = 1
    for a in batch_axes:
        nb *= mesh.shape[a]
    ba = batch_axes if (nb and b % nb == 0) else ()
    scale = cfg.head_dim ** -0.5

    def body(q_, new_t, cache_t, lens):
        kc = cache_t["k"]
        s_loc = kc.shape[1]
        start = jax.lax.axis_index("model") * s_loc
        slot_global = lens % cache_len if ring else lens
        local_slot = slot_global - start
        in_range = (local_slot >= 0) & (local_slot < s_loc)

        def upd(c, n, s, ok):
            s_cl = jnp.clip(s, 0, s_loc - 1)
            new = jax.lax.dynamic_update_slice(c, n, (s_cl, 0, 0))
            return jnp.where(ok, new, c)

        cache_t = jax.tree.map(
            lambda c, n: jax.vmap(upd)(c, n, local_slot, in_range),
            cache_t, new_t)
        if kv_q:
            kc = dequantize_kv(cache_t["k"], cache_t["k_scale"], q_.dtype)
            vc = dequantize_kv(cache_t["v"], cache_t["v_scale"], q_.dtype)
        else:
            kc, vc = cache_t["k"], cache_t["v"]

        # grouped-GQA attention WITHOUT materializing gqa_repeat: repeating
        # 4 kv heads to 32 q heads would read the cache 8x (measured as the
        # top HBM term of MoE decode) — index kv heads per q-head group
        # instead, exactly what a TPU flash kernel does
        hkv = kc.shape[2]
        g = cfg.num_heads // hkv
        qg = q_.reshape(q_.shape[0], 1, hkv, g, q_.shape[-1])  # (B,1,Hkv,G,hd)
        logits = jnp.einsum("bqhgd,bshd->bhgqs", qg, kc,
                            preferred_element_type=jnp.float32) * scale
        gidx = start + jnp.arange(s_loc)[None, :]          # (1, s_loc) global
        if ring:
            n_valid = jnp.minimum(lens[:, None] + 1, cache_len)
            mask = gidx < n_valid
        else:
            mask = gidx <= lens[:, None]
            if window is not None:
                mask = mask & (gidx > lens[:, None] - window)
        mask5 = mask[:, None, None, None, :]               # (B,1,1,1,s_loc)
        logits = jnp.where(mask5, logits, NEG_INF)
        m_loc = jnp.max(logits, axis=-1)                   # (B,Hkv,G,1)
        pr = jnp.exp(logits - m_loc[..., None])
        pr = jnp.where(mask5, pr, 0.0)
        l_loc = jnp.sum(pr, axis=-1)
        acc_loc = jnp.einsum("bhgqs,bshd->bhgqd", pr.astype(vc.dtype), vc,
                             preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m_loc, "model")
        corr = jnp.exp(m_loc - m_g)
        l_g = jax.lax.psum(l_loc * corr, "model")
        acc_g = jax.lax.psum(acc_loc * corr[..., None], "model")
        out = acc_g / jnp.maximum(l_g[..., None], 1e-30)   # (B,Hkv,G,1,hd)
        b_, _, _, _, hd = out.shape
        out = out.astype(q_.dtype).transpose(0, 3, 1, 2, 4)
        return out.reshape(b_, 1, hkv * g, hd), cache_t    # (B,1,H,hd)

    rep = jax.tree.map(lambda a: P(*([ba] + [None] * (a.ndim - 1))), new_tree)
    shd = jax.tree.map(lambda a: P(ba, "model", *([None] * (a.ndim - 2))),
                       cache_tree)
    out, new_cache = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(ba, None, None, None), rep, shd, P(ba)),
        out_specs=(P(ba, None, None, None), shd),
        check_vma=True,
    )(q, new_tree, cache_tree, lengths)
    out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim)
    out = quant.qdot("bse,ed->bsd", out, p["wo"])
    return out, new_cache


def use_cp_decode(cfg, mesh, cache_len: int) -> bool:
    """Sequence-sharded decode applies when a mesh with a 'model' axis is
    present, the cache length divides it, and the config opts in."""
    return (mesh is not None
            and "model" in getattr(mesh, "axis_names", ())
            and getattr(cfg, "kv_cache_shard", "seq") == "seq"
            and cache_len % mesh.shape["model"] == 0)


def attn_prefill(p: dict, cfg, cache: dict, x: Array, positions: Array,
                 window: int | None,
                 mrope_positions: Array | None = None,
                 mesh=None) -> tuple[Array, dict]:
    """Full-sequence forward that also fills the cache (non-ring layout only
    when S <= cache_len; for ring caches the last `window` tokens are kept)."""
    b, s, _ = x.shape
    cache_len = cache["k"].shape[1]
    q, k, v = _project_qkv(p, cfg, x, positions, mrope_positions, mesh)
    kr = gqa_repeat(k, cfg.num_heads)
    vr = gqa_repeat(v, cfg.num_heads)
    # q is already head-pinned inside _project_qkv; kr/vr changed head count
    kr = head_constrain(mesh, kr)
    vr = head_constrain(mesh, vr)
    if s >= CHUNK_THRESHOLD:
        out = attend_chunked(q, kr, vr, positions, positions, window,
                             cfg.head_dim ** -0.5)
    else:
        qp = positions[:, None, :, None]
        kp = positions[:, None, None, :]
        mask = kp <= qp
        if window is not None:
            mask = mask & (kp > qp - window)
        out = attend(q, kr, vr, mask, cfg.head_dim ** -0.5)
    out = out.reshape(b, s, cfg.num_heads * cfg.head_dim)
    out = quant.qdot("bse,ed->bsd", out, p["wo"])
    if s >= cache_len:
        # ring layout invariant: position p lives at slot p % cache_len, so the
        # kept tail [s-cache_len, s) must be rolled to line up with future
        # decode writes at slot (lengths % cache_len).
        k_keep = jnp.roll(k[:, s - cache_len:], shift=s, axis=1)
        v_keep = jnp.roll(v[:, s - cache_len:], shift=s, axis=1)
        if kv_quantized(cfg):
            kq, ks = quantize_kv(k_keep)
            vq, vs = quantize_kv(v_keep)
            cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        else:
            cache = {"k": k_keep.astype(cache["k"].dtype),
                     "v": v_keep.astype(cache["v"].dtype)}
    elif kv_quantized(cfg):
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        cache = {
            kk: jax.lax.dynamic_update_slice(cache[kk], nn, (0, 0, 0, 0))
            for kk, nn in (("k", kq), ("v", vq),
                           ("k_scale", ks), ("v_scale", vs))
        }
    else:
        cache = {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
        }
    return out, cache

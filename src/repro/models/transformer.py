"""Decoder stacks for every assigned architecture family.

Parameters are *prestacked* along a leading layer axis and the stack runs
under ``jax.lax.scan`` — the TPU realization of the paper's expert-wise
weights prestacking (C2): one contiguous array per weight kind, O(1) HLO
size in depth, and a layout the grouped-GEMM kernel can consume directly.
``prestack=False`` (naive baseline, Fig. 4's "unstacking") switches to a
python loop over per-layer arrays.

Families:
  dense / audio / vlm : attention + SwiGLU MLP
  moe                 : attention + expert-parallel MoE (core/expert_parallel)
  ssm                 : Mamba-2 SSD blocks (no MLP)
  hybrid              : RG-LRU x2 + local attention, each followed by MLP
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import expert_parallel
from repro.models import attention, layers, mamba2, rglru

Array = jax.Array


def seq_constrain(mesh, x: Array) -> Array:
    """Megatron-style sequence sharding of the residual stream over the
    'model' axis (beyond-paper activation-memory optimization; collectives
    around attention / MoE dispatch are inserted by GSPMD / shard_map)."""
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    b, s, _ = x.shape
    if s % mesh.shape["model"] != 0 or s < 2048:
        return x
    batch_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    nb = 1
    for a in batch_axes:
        nb *= mesh.shape[a]
    ba = batch_axes if (nb and b % nb == 0) else ()
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(ba, "model", None)))


# ---------------------------------------------------------------------------
# per-layer init (stacked via vmap)
# ---------------------------------------------------------------------------

def _dense_layer_init(cfg, dtype, key):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": layers.norm_init(cfg.norm, cfg.d_model, dtype),
        "attn": attention.attn_init(k1, cfg, dtype),
        "ln2": layers.norm_init(cfg.norm, cfg.d_model, dtype),
        "mlp": layers.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }
    return p


def _moe_layer_init(cfg, dtype, key):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    e, d, f = cfg.num_experts_padded, cfg.d_model, cfg.d_ff

    def expert_w(k, din, dout):
        ks = jax.random.split(k, e)
        return jax.vmap(lambda kk: layers.dense_init(kk, din, dout, dtype))(ks)

    experts = {
        "w_gate": expert_w(k3, d, f),
        "w_up": expert_w(k4, d, f),
        "w_down": expert_w(k5, f, d),
    }
    r = max(getattr(cfg, "expert_replication", 1), 1)
    if r > 1:
        # paper §5.3 overlapping placement: store r copies so each expert
        # lives on r expert-parallel shards ("use the extra memory")
        experts = jax.tree.map(
            lambda a: jnp.concatenate([a] * r, axis=0), experts)
    return {
        "ln1": layers.norm_init(cfg.norm, d, dtype),
        "attn": attention.attn_init(k1, cfg, dtype),
        "ln2": layers.norm_init(cfg.norm, d, dtype),
        "router": layers.dense_init(k2, d, e, dtype),
        "experts": experts,
    }


def _ssm_layer_init(cfg, dtype, key):
    return {
        "ln": layers.norm_init(cfg.norm, cfg.d_model, dtype),
        "mamba": mamba2.mamba_init(key, cfg, dtype),
    }


def _hybrid_layer_init(cfg, dtype, key, kind: str):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": layers.norm_init(cfg.norm, cfg.d_model, dtype),
        "ln2": layers.norm_init(cfg.norm, cfg.d_model, dtype),
        "mlp": layers.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }
    if kind == "rec":
        p["mix"] = rglru.rglru_init(k1, cfg, dtype)
    else:
        p["mix"] = attention.attn_init(k1, cfg, dtype)
    return p


def hybrid_pattern(cfg) -> list[str]:
    """rec,rec,attn repeating (RecurrentGemma's 1 attention per 2 recurrent)."""
    return ["attn" if i % 3 == 2 else "rec" for i in range(cfg.num_layers)]


def init_blocks(cfg, key) -> dict:
    dtype = cfg.param_dtype_jnp
    L = cfg.num_layers
    if cfg.family == "hybrid":
        pat = hybrid_pattern(cfg)
        rec_keys = jax.random.split(jax.random.fold_in(key, 0),
                                    pat.count("rec"))
        attn_keys = jax.random.split(jax.random.fold_in(key, 1),
                                     max(pat.count("attn"), 1))
        rec = jax.vmap(lambda k: _hybrid_layer_init(cfg, dtype, k, "rec"))(rec_keys)
        attn = jax.vmap(lambda k: _hybrid_layer_init(cfg, dtype, k, "attn"))(attn_keys)
        return {"rec": rec, "attn": attn}
    keys = jax.random.split(key, L)
    if cfg.family == "moe":
        f = lambda k: _moe_layer_init(cfg, dtype, k)
    elif cfg.family == "ssm":
        f = lambda k: _ssm_layer_init(cfg, dtype, k)
    else:
        f = lambda k: _dense_layer_init(cfg, dtype, k)
    return jax.vmap(f)(keys)


# ---------------------------------------------------------------------------
# forward (full-sequence): train and prefill share block bodies
# ---------------------------------------------------------------------------

def _attn_mlp_block(cfg, mesh, layer_p, x, positions, window, mrope_pos,
                    cache_l=None, decode=False, token_mask=None,
                    block_lens=None, block_tables=None, paged_kernel=False):
    """Generic attention(+cache) + {mlp | moe} block.

    Returns (x, new_cache, aux, routed) where ``routed`` is the MoE layer's
    per-token routing decision ((B*S, K) int32, see expert_parallel.moe_layer)
    or None for non-MoE families.  ``token_mask`` (B, S) bool marks tokens
    that may consume expert capacity (batched prefill masks garbage rows).

    ``block_lens`` = (lengths, seg_lens) selects the unified token-block
    path (attention.attn_block_step): an arbitrary (B, T) chunk appended at
    per-row cache offsets — chunked prefill and mixed prefill/decode batches
    share this one body (docs/DESIGN.md §6).  ``block_tables`` (B, NB)
    additionally selects the paged-cache form of that path: ``cache_l``
    holds page-pool leaves and each row reaches its cache through its
    block table (docs/DESIGN.md §7); ``paged_kernel`` swaps that path's
    virtual-cache gather for the Pallas block-table kernel (§11)."""
    h = layers.norm_apply(cfg.norm, layer_p["ln1"], x)
    if block_lens is not None and block_tables is not None:
        lengths, seg_lens = block_lens
        h, new_cache = attention.attn_block_step_paged(
            layer_p["attn"], cfg, cache_l, h, positions, lengths, seg_lens,
            block_tables, window, mrope_pos, mesh=mesh,
            use_kernel=paged_kernel)
    elif block_lens is not None:
        lengths, seg_lens = block_lens
        h, new_cache = attention.attn_block_step(
            layer_p["attn"], cfg, cache_l, h, positions, lengths, seg_lens,
            window, mrope_pos, mesh=mesh)
    elif decode:
        if attention.use_cp_decode(cfg, mesh, cache_l["k"].shape[1]):
            h, new_cache = attention.attn_decode_step_cp(
                layer_p["attn"], cfg, cache_l, h, positions, window, mesh,
                mrope_pos)
        else:
            h, new_cache = attention.attn_decode_step(
                layer_p["attn"], cfg, cache_l, h, positions, window, mrope_pos,
                mesh=mesh)
    elif cache_l is not None:
        pos2d = positions if positions.ndim == 2 else positions[None]
        h, new_cache = attention.attn_prefill(
            layer_p["attn"], cfg, cache_l, h, pos2d, window, mrope_pos,
            mesh=mesh)
    else:
        pos2d = positions if positions.ndim == 2 else positions[None]
        h = attention.attn_forward(layer_p["attn"], cfg, h, pos2d, window,
                                   mrope_pos, mesh=mesh)
        new_cache = None
    if not decode:
        # constrain at the produce site: the TP partial-sum of wo is
        # reduce-SCATTERED into the sequence-sharded residual instead of
        # all-reduced at full length (Megatron sequence parallelism)
        h = seq_constrain(mesh, h)
    x = x + h
    h = layers.norm_apply(cfg.norm, layer_p["ln2"], x)
    if cfg.family == "moe":
        moe_p = {"router": layer_p["router"], "experts": layer_p["experts"]}
        h, aux, routed = expert_parallel.moe_layer(cfg, mesh, moe_p, h,
                                                   token_mask)
    else:
        h = layers.mlp_apply(layer_p["mlp"], h, cfg.act)
        aux = jnp.zeros((), jnp.float32)
        routed = None
    if not decode:
        h = seq_constrain(mesh, h)
    return x + h, new_cache, aux, routed


def _ssm_block(cfg, layer_p, x, cache_l=None, decode=False):
    h = layers.norm_apply(cfg.norm, layer_p["ln"], x)
    if decode:
        h, new_cache = mamba2.mamba_decode_step(layer_p["mamba"], cfg, cache_l, h)
    elif cache_l is not None:
        h, new_cache = mamba2.mamba_forward(layer_p["mamba"], cfg, h,
                                            state=cache_l)
    else:
        h = mamba2.mamba_forward(layer_p["mamba"], cfg, h)
        new_cache = None
    return x + h, new_cache, jnp.zeros((), jnp.float32)


def _hybrid_block(cfg, layer_p, kind, x, positions, cache_l=None, decode=False,
                  mesh=None):
    h = layers.norm_apply(cfg.norm, layer_p["ln1"], x)
    if kind == "rec":
        if decode:
            h, new_cache = rglru.rglru_decode_step(layer_p["mix"], cfg, cache_l, h)
        elif cache_l is not None:
            h, new_cache = rglru.rglru_forward(layer_p["mix"], cfg, h,
                                               state=cache_l)
        else:
            h = rglru.rglru_forward(layer_p["mix"], cfg, h)
            new_cache = None
    else:
        w = cfg.sliding_window
        if decode:
            if attention.use_cp_decode(cfg, mesh, cache_l["k"].shape[1]):
                h, new_cache = attention.attn_decode_step_cp(
                    layer_p["mix"], cfg, cache_l, h, positions, w, mesh)
            else:
                h, new_cache = attention.attn_decode_step(
                    layer_p["mix"], cfg, cache_l, h, positions, w, mesh=mesh)
        elif cache_l is not None:
            pos2d = positions if positions.ndim == 2 else positions[None]
            h, new_cache = attention.attn_prefill(layer_p["mix"], cfg, cache_l,
                                                  h, pos2d, w, mesh=mesh)
        else:
            pos2d = positions if positions.ndim == 2 else positions[None]
            h = attention.attn_forward(layer_p["mix"], cfg, h, pos2d, w,
                                       mesh=mesh)
            new_cache = None
    x = x + h
    h = layers.norm_apply(cfg.norm, layer_p["ln2"], x)
    h = layers.mlp_apply(layer_p["mlp"], h, cfg.act)
    return x + h, new_cache, jnp.zeros((), jnp.float32)


def _maybe_remat(cfg, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def forward_stack(cfg, mesh, blocks, x, positions, window, mrope_pos=None):
    """Run all layers over a full sequence. Returns (x, total_aux)."""
    if cfg.family == "hybrid":
        pat = hybrid_pattern(cfg)
        aux = jnp.zeros((), jnp.float32)
        ri = ai = 0
        for kind in pat:
            if kind == "rec":
                lp = jax.tree.map(lambda a: a[ri], blocks["rec"])
                ri += 1
            else:
                lp = jax.tree.map(lambda a: a[ai], blocks["attn"])
                ai += 1
            fn = _maybe_remat(cfg, lambda xx, lp=lp, kind=kind: _hybrid_block(
                cfg, lp, kind, seq_constrain(mesh, xx), positions,
                mesh=mesh)[0])
            x = fn(x)
        return x, aux

    if cfg.family == "ssm":
        def body(xx, lp):
            out, _, aux = _ssm_block(cfg, lp, seq_constrain(mesh, xx))
            return out, aux
    else:
        def body(xx, lp):
            out, _, aux, _ = _attn_mlp_block(cfg, mesh, lp,
                                             seq_constrain(mesh, xx),
                                             positions, window, mrope_pos)
            return out, aux

    if cfg.prestack:
        x, auxs = jax.lax.scan(
            lambda c, lp: _maybe_remat(cfg, body)(c, lp), x, blocks)
        aux = jnp.sum(auxs)
    else:
        # naive "unstacked" layout: python loop over per-layer slices
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], blocks)
            x, a = _maybe_remat(cfg, body)(x, lp)
            aux = aux + a
    return x, aux


# ---------------------------------------------------------------------------
# cached paths (prefill / decode) — caches stacked along the layer axis
# ---------------------------------------------------------------------------

def stack_cache_spec(cfg, batch: int, cache_len: int, dtype):
    L = cfg.num_layers
    if cfg.family == "ssm":
        per = mamba2.mamba_cache_spec(cfg, batch, dtype)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((L,) + s.shape, s.dtype), per)
    if cfg.family == "hybrid":
        pat = hybrid_pattern(cfg)
        rec = rglru.rglru_cache_spec(cfg, batch, dtype)
        attn_len = min(cache_len, cfg.sliding_window or cache_len)
        att = attention.layer_cache_spec(cfg, batch, attn_len, dtype)
        return {
            "rec": jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                (pat.count("rec"),) + s.shape, s.dtype), rec),
            "attn": jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                (pat.count("attn"),) + s.shape, s.dtype), att),
        }
    win = effective_window(cfg, cache_len)
    clen = min(cache_len, win) if win else cache_len
    per = attention.layer_cache_spec(cfg, batch, clen, dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((L,) + s.shape, s.dtype), per)


def init_stack_cache(cfg, batch: int, cache_len: int, dtype):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        stack_cache_spec(cfg, batch, cache_len, dtype))


def paged_stack_cache_spec(cfg, num_pages: int, page_size: int, dtype):
    """Stacked paged pool: one ``(L, num_pages, page_size, Hkv, hd)`` leaf
    per cache kind (docs/DESIGN.md §7).  Only token-input attention
    families page their cache; ssm/hybrid state is per-row and stays on
    the contiguous layout."""
    if cfg.family not in ("dense", "moe", "vlm", "audio"):
        raise NotImplementedError(
            f"paged KV cache supports attention-cache families, not "
            f"{cfg.family!r}")
    per = attention.paged_layer_cache_spec(cfg, num_pages, page_size, dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.num_layers,) + s.shape, s.dtype),
        per)


def init_paged_stack_cache(cfg, num_pages: int, page_size: int, dtype):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        paged_stack_cache_spec(cfg, num_pages, page_size,
                                               dtype))


def effective_window(cfg, seq_len: int) -> int | None:
    """Window actually used at this sequence length: native sliding window if
    the arch has one; the long-context SWA variant kicks in beyond
    ``cfg.long_context_threshold`` for otherwise-full-attention archs."""
    if cfg.sliding_window:
        return cfg.sliding_window
    if cfg.long_context_window and seq_len >= cfg.long_context_threshold:
        return cfg.long_context_window
    return None


def _scan_stack_with_cache(cfg, blocks, x, cache, layer_body):
    """Run ``layer_body`` over all layers with the *whole* stacked cache as
    part of the scan carry (donation-safe zero-copy layout).

    The cache used to stream through the scan as an xs input and come back
    stacked as a ys output — a layout that forces XLA to double-buffer it
    (fresh ys allocation + full-size copies every step) even when the jit
    caller donates the buffer.  Carrying the stack instead and updating
    layer l's slice with ``dynamic_update_index_in_dim`` lets the compiled
    while-loop alias the donated input in place: the decode step's cache
    traffic is exactly one layer-slice write per layer, never a full-cache
    copy (regression-tested against the lowered HLO in
    tests/test_zero_copy.py).

    ``layer_body(x, layer_p, cache_l) -> (x, new_cache_l, routed)``.
    Returns (x, new_cache, routing_ys)."""

    def body(carry, inp):
        xx, full_cache = carry
        lp, l = inp
        cl = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, l, axis=0,
                                                   keepdims=False),
            full_cache)
        xx, ncl, routed = layer_body(xx, lp, cl)
        full_cache = jax.tree.map(
            lambda a, n: jax.lax.dynamic_update_index_in_dim(a, n, l, axis=0),
            full_cache, ncl)
        return (xx, full_cache), routed

    (x, new_cache), routing = jax.lax.scan(
        body, (x, cache), (blocks, jnp.arange(cfg.num_layers)))
    return x, new_cache, routing


def decode_stack(cfg, mesh, blocks, x, lengths, cache, window,
                 mrope_pos=None, token_mask=None):
    """One-token decode through all layers. x: (B,1,D).

    Returns (x, new_cache, routing) — ``routing`` is the stacked per-layer
    MoE decision (L, B, K) int32 for the moe family, else None.  It rides
    out of the scan as a ys output, so capturing it costs no extra router
    evaluation (the serving engine's tracker consumes it device-side).

    The cache travels through the layer scan as a carry updated in place
    (see ``_scan_stack_with_cache``), so a caller that donates it gets a
    zero-copy steady-state decode step."""
    if cfg.family == "hybrid":
        pat = hybrid_pattern(cfg)
        new_rec, new_attn = [], []
        ri = ai = 0
        for kind in pat:
            if kind == "rec":
                lp = jax.tree.map(lambda a: a[ri], blocks["rec"])
                cl = jax.tree.map(lambda a: a[ri], cache["rec"])
                x, nc, _ = _hybrid_block(cfg, lp, "rec", x, lengths, cl,
                                         decode=True, mesh=mesh)
                new_rec.append(nc)
                ri += 1
            else:
                lp = jax.tree.map(lambda a: a[ai], blocks["attn"])
                cl = jax.tree.map(lambda a: a[ai], cache["attn"])
                x, nc, _ = _hybrid_block(cfg, lp, "attn", x, lengths, cl,
                                         decode=True, mesh=mesh)
                new_attn.append(nc)
                ai += 1
        stack = lambda lst: jax.tree.map(lambda *a: jnp.stack(a), *lst)
        return x, {"rec": stack(new_rec), "attn": stack(new_attn)}, None

    if cfg.family == "ssm":
        def layer_body(xx, lp, cl):
            out, nc, _ = _ssm_block(cfg, lp, xx, cl, decode=True)
            return out, nc, jnp.zeros((), jnp.int32)
        x, new_cache, _ = _scan_stack_with_cache(cfg, blocks, x, cache,
                                                 layer_body)
        return x, new_cache, None

    def layer_body(xx, lp, cl):
        out, nc, _, routed = _attn_mlp_block(cfg, mesh, lp, xx, lengths,
                                             window, mrope_pos, cl,
                                             decode=True,
                                             token_mask=token_mask)
        if routed is None:           # dense/vlm/audio: no capture
            routed = jnp.zeros((), jnp.int32)
        return out, nc, routed

    x, new_cache, routing = _scan_stack_with_cache(cfg, blocks, x, cache,
                                                   layer_body)
    if cfg.family != "moe":
        routing = None
    return x, new_cache, routing


def unified_stack(cfg, mesh, blocks, x, positions, lengths, seg_lens, cache,
                  window, mrope_pos=None, token_mask=None, block_tables=None,
                  paged_kernel=False):
    """Length-agnostic token-block forward through all layers — the ONE
    layer body behind chunked prefill, decode, and mixed prefill/decode
    batches (the prefill/decode twin stacks remain as the
    ``unified_step=False`` reference path).

    x: (B, T, D); positions: (B, T) absolute; lengths/seg_lens: (B,) cache
    offsets and per-row valid-token counts.  Returns (x, new_cache,
    routing) with routing (L, B*T, K) int32 for the moe family (invalid
    tokens read the E_pad sentinel), else None.  The cache rides the layer
    scan as a carry (``_scan_stack_with_cache``), so a donating caller
    keeps the zero-copy hot loop.  With ``block_tables`` (B, NB) the cache
    is the paged pool of ``paged_stack_cache_spec`` and every row reaches
    its slots through its block table (docs/DESIGN.md §7) — same carry,
    same zero-copy property.  ``paged_kernel`` routes the paged path
    through the Pallas block-table attention kernel instead of the
    virtual-cache gather (docs/DESIGN.md §11)."""
    if cfg.family not in ("dense", "moe", "vlm", "audio"):
        raise NotImplementedError(
            f"unified_stack supports attention-cache families, not "
            f"{cfg.family!r} (use the prefill/decode reference path)")

    def layer_body(xx, lp, cl):
        out, nc, _, routed = _attn_mlp_block(cfg, mesh, lp, xx, positions,
                                             window, mrope_pos, cl,
                                             token_mask=token_mask,
                                             block_lens=(lengths, seg_lens),
                                             block_tables=block_tables,
                                             paged_kernel=paged_kernel)
        if routed is None:
            routed = jnp.zeros((), jnp.int32)
        return out, nc, routed

    x, new_cache, routing = _scan_stack_with_cache(cfg, blocks, x, cache,
                                                   layer_body)
    if cfg.family != "moe":
        routing = None
    return x, new_cache, routing


def prefill_stack(cfg, mesh, blocks, x, positions, cache, window,
                  mrope_pos=None, token_mask=None):
    """Full-sequence forward that fills the cache.

    Returns (x, new_cache, routing) — ``routing`` is (L, B*S, K) int32 for
    the moe family (per-layer device-side routing capture), else None."""
    if cfg.family == "hybrid":
        pat = hybrid_pattern(cfg)
        new_rec, new_attn = [], []
        ri = ai = 0
        for kind in pat:
            x = seq_constrain(mesh, x)
            if kind == "rec":
                lp = jax.tree.map(lambda a: a[ri], blocks["rec"])
                cl = jax.tree.map(lambda a: a[ri], cache["rec"])
                x, nc, _ = _hybrid_block(cfg, lp, "rec", x, positions, cl,
                                         mesh=mesh)
                new_rec.append(nc)
                ri += 1
            else:
                lp = jax.tree.map(lambda a: a[ai], blocks["attn"])
                cl = jax.tree.map(lambda a: a[ai], cache["attn"])
                x, nc, _ = _hybrid_block(cfg, lp, "attn", x, positions, cl,
                                         mesh=mesh)
                new_attn.append(nc)
                ai += 1
        stack = lambda lst: jax.tree.map(lambda *a: jnp.stack(a), *lst)
        return x, {"rec": stack(new_rec), "attn": stack(new_attn)}, None

    if cfg.family == "ssm":
        def layer_body(xx, lp, cl):
            out, nc, _ = _ssm_block(cfg, lp, seq_constrain(mesh, xx), cl)
            return out, nc, jnp.zeros((), jnp.int32)
        x, new_cache, _ = _scan_stack_with_cache(cfg, blocks, x, cache,
                                                 layer_body)
        return x, new_cache, None

    def layer_body(xx, lp, cl):
        out, nc, _, routed = _attn_mlp_block(cfg, mesh, lp,
                                             seq_constrain(mesh, xx),
                                             positions, window, mrope_pos,
                                             cl, token_mask=token_mask)
        if routed is None:
            routed = jnp.zeros((), jnp.int32)
        return out, nc, routed

    x, new_cache, routing = _scan_stack_with_cache(cfg, blocks, x, cache,
                                                   layer_body)
    if cfg.family != "moe":
        routing = None
    return x, new_cache, routing

"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD for train/prefill (quadratic intra-chunk dual form + sequential
inter-chunk state recurrence via ``lax.scan``), O(1)-state recurrent update
for decode.  This is the attention-free family assigned to the framework —
the paper's expert-parallel technique is inapplicable here (documented in
docs/DESIGN.md §Arch-applicability); the block runs under data parallelism.

Shapes follow the reference: x is split into H heads of P=headdim channels;
state is (H, P, N) with N = d_state; B/C are shared across heads (n_groups=1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

Array = jax.Array


def d_inner(cfg) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_heads(cfg) -> int:
    return d_inner(cfg) // cfg.ssm_headdim


def conv_dim(cfg) -> int:
    return d_inner(cfg) + 2 * cfg.ssm_state


def mamba_init(key: Array, cfg, dtype) -> dict:
    d = cfg.d_model
    di, h, n = d_inner(cfg), n_heads(cfg), cfg.ssm_state
    dc = conv_dim(cfg)
    ks = jax.random.split(key, 4)
    # in_proj emits [z (di), xBC (dc), dt (h)]
    return {
        "in_proj": layers.dense_init(ks[0], d, 2 * di + 2 * n + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_dconv, dc), jnp.float32)
                   * (1.0 / jnp.sqrt(cfg.ssm_dconv))).astype(dtype),
        "conv_b": jnp.zeros((dc,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": layers.dense_init(ks[2], di, d, dtype),
    }


def _split_proj(cfg, zxbcdt: Array):
    di, h, n = d_inner(cfg), n_heads(cfg), cfg.ssm_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv1d. xbc: (B,S,C), w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k))
    return jax.nn.silu(out + b)


def _ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array,
                 chunk: int, h0: Array | None):
    """Chunked SSD scan.

    x: (b, s, h, p); dt: (b, s, h) (already softplus'ed); A: (h,) negative;
    B, C: (b, s, n).  Returns y (b, s, h, p) and final state (b, h, p, n).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    c = s // chunk
    xc = x.reshape(b, c, chunk, h, p)
    dtc = dt.reshape(b, c, chunk, h)
    Bc = B.reshape(b, c, chunk, n)
    Cc = C.reshape(b, c, chunk, n)

    dA = dtc * A[None, None, None, :]                      # (b,c,q,h) <= 0
    dA_cs = jnp.cumsum(dA, axis=2)                          # inclusive cumsum
    # intra-chunk dual (quadratic) form
    # L[i,j] = exp(dA_cs[i] - dA_cs[j]) for j <= i
    seg = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]  # (b,c,i,j,h)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    cmask = causal[None, None, :, :, None]
    # mask BEFORE exp: the non-causal triangle has seg > 0 and exp overflows
    # to inf, which turns the where's backward into inf*0 = NaN
    seg = jnp.where(cmask, seg, 0.0)
    L = jnp.where(cmask, jnp.exp(seg), 0.0)
    CB = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))                 # (b,c,i,j)
    att = CB[..., None] * L                                 # (b,c,i,j,h)
    xdt = xc.astype(jnp.float32) * dtc[..., None]           # (b,c,q,h,p)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xdt)

    # chunk-local end states: sum_j exp(dA_cs[-1] - dA_cs[j]) * dt_j * B_j x_j
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)     # (b,c,q,h)
    states = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                        decay_to_end * dtc, Bc.astype(jnp.float32),
                        xc.astype(jnp.float32))             # (b,c,h,p,n)

    # inter-chunk recurrence over c
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])               # (b,c,h)
    init = jnp.zeros((b, h, p, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(carry, inp):
        st, dec = inp  # (b,h,p,n), (b,h)
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit state *entering* the chunk

    final, entry_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    entry_states = entry_states.transpose(1, 0, 2, 3, 4)    # (b,c,h,p,n)

    # contribution of the entering state to each position in the chunk
    state_decay = jnp.exp(dA_cs)                            # (b,c,q,h)
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                         Cc.astype(jnp.float32), entry_states, state_decay)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, final


def mamba_forward(p: dict, cfg, x: Array, state: dict | None = None,
                  chunk: int = 256):
    """Full-sequence SSD. x: (B,S,D) -> (B,S,D). If ``state`` is given the
    final (conv, ssm) states are also returned for cache handoff."""
    b, s, d = x.shape
    h, pdim, n = n_heads(cfg), cfg.ssm_headdim, cfg.ssm_state
    di = d_inner(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di].reshape(b, s, h, pdim)
    B = xbc[..., di:di + n]
    C = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    ck = min(chunk, s) if s % min(chunk, s) == 0 else s
    y, final = _ssd_chunked(xs, dt, A, B, C, ck, None)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = layers.rms_norm(p["norm"], y * jax.nn.silu(z))
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if state is not None:
        conv_state = xbc_raw_tail(zxbcdt, cfg, cfg.ssm_dconv).astype(x.dtype)
        return out, {"conv": conv_state, "ssm": final.astype(jnp.float32)}
    return out


def xbc_raw_tail(zxbcdt: Array, cfg, k: int) -> Array:
    """Last k-1 pre-conv xBC activations, padded on the left if S < k-1."""
    di, n = d_inner(cfg), cfg.ssm_state
    xbc = zxbcdt[..., di:di + di + 2 * n]
    s = xbc.shape[1]
    if s >= k - 1:
        return xbc[:, s - (k - 1):, :]
    return jnp.pad(xbc, ((0, 0), (k - 1 - s, 0), (0, 0)))


def init_mamba_cache(cfg, batch: int, dtype) -> dict:
    h, pdim, n = n_heads(cfg), cfg.ssm_headdim, cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_dconv - 1, conv_dim(cfg)), dtype),
        "ssm": jnp.zeros((batch, h, pdim, n), jnp.float32),
    }


def mamba_cache_spec(cfg, batch: int, dtype) -> dict:
    h, pdim, n = n_heads(cfg), cfg.ssm_headdim, cfg.ssm_state
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_dconv - 1, conv_dim(cfg)), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, h, pdim, n), jnp.float32),
    }


def mamba_decode_step(p: dict, cfg, cache: dict, x: Array):
    """x: (B, 1, D) -> (B, 1, D), cache'. Recurrent O(1) update."""
    b = x.shape[0]
    h, pdim, n = n_heads(cfg), cfg.ssm_headdim, cfg.ssm_state
    di = d_inner(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc_new, dt = _split_proj(cfg, zxbcdt)

    # depthwise causal conv over [cache.conv ; xbc_new]
    win = jnp.concatenate([cache["conv"].astype(xbc_new.dtype), xbc_new], axis=1)
    k = cfg.ssm_dconv
    conv_out = jnp.einsum("bkc,kc->bc", win.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(conv_out)[:, None, :]                 # (B,1,C)
    new_conv = win[:, 1:, :].astype(cache["conv"].dtype)

    xs = xbc[..., :di].reshape(b, h, pdim)
    B = xbc[:, 0, di:di + n]
    C = xbc[:, 0, di + n:]
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,h)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dtv * A[None, :])                          # (B,h)
    ssm = cache["ssm"] * dA[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dtv, B.astype(jnp.float32), xs.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", C.astype(jnp.float32), ssm)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = layers.rms_norm(p["norm"], y * jax.nn.silu(z))
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"conv": new_conv, "ssm": ssm}

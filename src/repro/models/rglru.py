"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

The hybrid architecture interleaves two recurrent (RG-LRU) blocks with one
local-attention block (pattern rec,rec,attn).  The RG-LRU recurrence is a
per-channel (diagonal) gated linear recurrence:

    r_t = sigmoid(x_t W_a + b_a)              (recurrence gate)
    i_t = sigmoid(x_t W_x + b_x)              (input gate)
    log a_t = -c * r_t * softplus(Lambda)     (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses ``jax.lax.associative_scan`` over the sequence (the
diagonal recurrence composes associatively); decode is the O(1) step.
Being per-channel diagonal, the recurrence shards cleanly over the channel
dimension — this is the recurrent-scan sharding noted in docs/DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

Array = jax.Array
_C = 8.0


def rglru_init(key: Array, cfg, dtype) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    # Lambda init so that a = sigmoid(Lambda)^c is in (0.9, 0.999)
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log(u)/c)
    return {
        "in_x": layers.dense_init(ks[1], d, w, dtype),
        "in_y": layers.dense_init(ks[2], d, w, dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv1d_width, w), jnp.float32)
                   * (1.0 / jnp.sqrt(cfg.conv1d_width))).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "gate_a": layers.dense_init(ks[4], w, w, dtype),
        "bias_a": jnp.zeros((w,), jnp.float32),
        "gate_x": layers.dense_init(ks[5], w, w, dtype),
        "bias_x": jnp.zeros((w,), jnp.float32),
        "Lambda": lam,
        "out": layers.dense_init(jax.random.fold_in(key, 7), w, d, dtype),
    }


def _causal_conv(x: Array, w: Array, b: Array, state: Array | None):
    """Depthwise causal conv; x: (B,S,W). If state (B,K-1,W) given, prepends it."""
    k = w.shape[0]
    if state is None:
        pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b, pad[:, pad.shape[1] - (k - 1):, :]


def _rglru_gates(p: dict, x: Array):
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x, p["gate_a"]).astype(jnp.float32)
                       + p["bias_a"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x, p["gate_x"]).astype(jnp.float32)
                       + p["bias_x"])
    log_a = -_C * r * jax.nn.softplus(p["Lambda"])[None, None, :]
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * x.astype(jnp.float32))
    return a, gated_x


def _linear_scan(a: Array, bx: Array, h0: Array | None):
    """h_t = a_t h_{t-1} + bx_t via associative scan. a, bx: (B,S,W) fp32."""
    if h0 is not None:
        # fold initial state into the first step
        bx = bx.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def rglru_forward(p: dict, cfg, x: Array, state: dict | None = None):
    """Recurrent block over a full sequence. x: (B,S,D)."""
    y_branch = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["in_y"]))
    xb = jnp.einsum("bsd,dw->bsw", x, p["in_x"])
    conv_state = state.get("conv") if state else None
    xb, new_conv = _causal_conv(xb, p["conv_w"], p["conv_b"], conv_state)
    a, bx = _rglru_gates(p, xb)
    h0 = state.get("h") if state else None
    h = _linear_scan(a, bx, h0)
    out = (h.astype(x.dtype) * y_branch)
    out = jnp.einsum("bsw,wd->bsd", out, p["out"])
    if state is not None:
        return out, {"conv": new_conv.astype(x.dtype), "h": h[:, -1, :]}
    return out


def init_rglru_cache(cfg, batch: int, dtype) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, cfg.lru_width), dtype),
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }


def rglru_cache_spec(cfg, batch: int, dtype) -> dict:
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv1d_width - 1, cfg.lru_width), dtype),
        "h": jax.ShapeDtypeStruct((batch, cfg.lru_width), jnp.float32),
    }


def rglru_decode_step(p: dict, cfg, cache: dict, x: Array):
    """x: (B,1,D) -> (B,1,D), cache'."""
    y_branch = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["in_y"]))
    xb = jnp.einsum("bsd,dw->bsw", x, p["in_x"])
    xb, new_conv = _causal_conv(xb, p["conv_w"], p["conv_b"], cache["conv"])
    a, bx = _rglru_gates(p, xb)  # (B,1,W)
    h = a[:, 0] * cache["h"] + bx[:, 0]
    out = (h[:, None, :].astype(x.dtype) * y_branch)
    out = jnp.einsum("bsw,wd->bsd", out, p["out"])
    return out, {"conv": new_conv.astype(x.dtype), "h": h}

"""Core NN layers shared by every architecture.

Pure-functional JAX: params are pytrees of jnp arrays, every function is
``f(params, x, ...) -> y``. Compute follows a bf16-weights / fp32-accumulate
policy; norms and softmax always run in fp32.  Weight matmuls go through
``core/quant.qdot`` so raw and blockwise-quantized (``QuantTensor``) weight
leaves are interchangeable (docs/DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import quant

Array = jax.Array


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key: Array, d_in: int, d_out: int, dtype) -> Array:
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key: Array, vocab: int, d: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

def rms_norm(w: Array, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(dt)


def layer_norm(params: dict, x: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dt)


def norm_apply(kind: str, params: Any, x: Array) -> Array:
    if kind == "layernorm":
        return layer_norm(params, x)
    return rms_norm(params, x)


def norm_init(kind: str, d: int, dtype) -> Any:
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE and qwen2-vl multimodal M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    """Inverse frequencies, shape (head_dim // 2,), fp32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions3: Array, theta: float,
                sections: tuple[int, int, int]) -> Array:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, hd); positions3: (B, S, 3) int32 (temporal, height, width).
    ``sections`` gives the number of *frequency pairs* per component and must
    sum to hd // 2 (e.g. (16, 24, 24) for hd=128).
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    inv = rope_freqs(hd, theta)  # (hd/2,)
    # angle per component, then select component per frequency-band section
    ang_all = positions3[..., None, :].astype(jnp.float32) * inv[None, None, :, None]
    # ang_all: (B, S, hd/2, 3)
    sel = jnp.concatenate([
        jnp.full((sections[0],), 0, jnp.int32),
        jnp.full((sections[1],), 1, jnp.int32),
        jnp.full((sections[2],), 2, jnp.int32),
    ])  # (hd/2,)
    ang = jnp.take_along_axis(ang_all, sel[None, None, :, None], axis=-1)[..., 0]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_init(key: Array, d: int, f: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, f, dtype),
        "w_up": dense_init(k2, d, f, dtype),
        "w_down": dense_init(k3, f, d, dtype),
    }


def mlp_apply(params: dict, x: Array, act: str = "silu") -> Array:
    g = quant.qdot("...d,df->...f", x, params["w_gate"])
    u = quant.qdot("...d,df->...f", x, params["w_up"])
    h = (jax.nn.gelu(g) if act == "gelu" else jax.nn.silu(g)) * u
    return quant.qdot("...f,fd->...d", h, params["w_down"])


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: Array, labels: Array, valid_vocab: int) -> Array:
    """CE over possibly vocab-padded logits. logits: (..., Vpad), labels int."""
    vpad = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if vpad != valid_vocab:
        mask = jnp.arange(vpad) < valid_vocab
        lf = jnp.where(mask, lf, -1e9)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return logz - gold

"""Finding / Rule / Report: the common core every analysis rule feeds.

A ``Rule`` inspects one traced program (or the engine / its source) and
returns ``Finding``s.  ``run_rules`` fans a rule set over a program set,
applies severity overrides (``warn_only``), and folds everything into a
``Report`` that the CLI can print and CI can gate on (``report.ok``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation (or observation), machine-readable.

    ``rule`` is the stable id ("R1".."R6"), ``name`` the human slug
    ("donation-alias"), ``program`` the traced program it was found in
    ("decode", "unified", ... or "engine" / "source" for non-HLO rules).
    ``detail`` carries rule-specific structured context (leaf paths,
    byte counts, line numbers)."""
    rule: str
    name: str
    severity: str
    program: str
    message: str
    detail: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return (f"[{self.severity.upper():7s}] {self.rule} "
                f"{self.name} @ {self.program}: {self.message}")


class Rule:
    """Base class.  Subclasses set ``rule_id`` / ``name`` / ``requires``
    and implement ``check(program)``.

    ``requires`` declares the front-end the rule consumes:
      * "hlo"    — a TracedProgram with compiled HLO text
      * "jaxpr"  — a TracedProgram that can produce a closed jaxpr
      * "engine" — a live ServingEngine to drive (R3)
      * "source" — the engine's Python source (R4)
    The runner only hands a rule inputs of its declared kind."""
    rule_id = "R0"
    name = "base"
    description = ""
    requires = "hlo"
    default_severity = "error"

    def check(self, program) -> list[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, program: str, message: str, *, severity: str | None = None,
                **detail) -> Finding:
        return Finding(rule=self.rule_id, name=self.name,
                       severity=severity or self.default_severity,
                       program=program, message=message, detail=detail)


@dataclasses.dataclass
class Report:
    findings: list = dataclasses.field(default_factory=list)
    programs: list = dataclasses.field(default_factory=list)
    rules: list = dataclasses.field(default_factory=list)

    @property
    def errors(self) -> list:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> list:
        return [f for f in self.findings if f.severity == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def by_rule(self, rule_id: str) -> list:
        return [f for f in self.findings if f.rule == rule_id]

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "programs": list(self.programs),
            "rules": list(self.rules),
            "n_errors": len(self.errors),
            "n_warnings": len(self.warnings),
            "findings": [f.to_dict() for f in self.findings],
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), indent=2, **kw)

    def summary(self) -> str:
        lines = [f"analysis: {len(self.rules)} rules x "
                 f"{len(self.programs)} programs -> "
                 f"{len(self.errors)} error(s), "
                 f"{len(self.warnings)} warning(s)"]
        for f in self.findings:
            lines.append("  " + str(f))
        if not self.findings:
            lines.append("  clean: no findings")
        return "\n".join(lines)


def _demote(findings: Iterable[Finding], warn_only: set) -> list[Finding]:
    out = []
    for f in findings:
        if f.rule in warn_only and f.severity == "error":
            f = dataclasses.replace(f, severity="warning")
        out.append(f)
    return out


def run_rules(rules, programs, *, warn_only: Iterable[str] = ()) -> Report:
    """Run every HLO/jaxpr rule over every traced program.

    Rules with ``requires`` other than "hlo"/"jaxpr" (engine- and
    source-level rules) are the caller's job — they don't take a traced
    program; pass their findings through ``Report.findings`` directly or
    demote them with ``demote_findings``."""
    warn_only = set(warn_only)
    rep = Report(programs=[p.name for p in programs],
                 rules=[r.rule_id for r in rules])
    for rule in rules:
        if rule.requires not in ("hlo", "jaxpr"):
            continue
        for prog in programs:
            rep.findings.extend(_demote(rule.check(prog), warn_only))
    return rep


def demote_findings(findings, warn_only: Iterable[str]) -> list[Finding]:
    """Public severity-override helper for engine/source-level findings."""
    return _demote(findings, set(warn_only))

"""R2 collective-bytes budget: the paper's latency accounting, statically.

Origin: PR1 (expert-parallel schedules), paper §5.2 — expert communication
time ≈ expert computation time, so the BYTES each schedule moves per layer
is a pinned quantity.  ``core/perf_model.predicted_collective_bytes``
mirrors ``core/expert_parallel``'s schedule bodies analytically; this rule
compares those predictions against ``launch/hlo.analyze``'s per-kind,
trip-multiplied actuals for the compiled program.

On a single device the prediction is empty and the rule degrades to the
strongest possible form: a serving program may contain NO collective at
all above a small floor (scalar aux pmeans are below it).  On a mesh,
predicted kinds must match within ``rel_tol``; collective kinds the model
does not predict (e.g. attention context-parallel traffic) are reported
as warnings rather than errors so schedule budgeting stays the gate.
"""
from __future__ import annotations

from repro.analysis.framework import Rule
from repro.core import perf_model
from repro.launch import hlo


class CollectiveBudgetRule(Rule):
    rule_id = "R2"
    name = "collective-bytes"
    description = ("per-kind collective bytes match core/perf_model "
                   "schedule predictions")
    requires = "hlo"

    def __init__(self, rel_tol: float = 0.5, abs_floor: int = 4096):
        self.rel_tol = rel_tol
        self.abs_floor = abs_floor

    def check(self, prog):
        findings = []
        actual = {k: float(v)
                  for k, v in hlo.analyze(prog.hlo_text).coll.items()}
        pred = perf_model.predicted_collective_bytes(
            prog.cfg, batch=prog.batch, seq=prog.seq,
            n_exp_shards=prog.n_exp_shards,
            n_batch_shards=prog.n_batch_shards)
        if not pred:
            for kind, nb in sorted(actual.items()):
                if nb >= self.abs_floor:
                    findings.append(self.finding(
                        prog.name,
                        f"{kind} moves {nb:.0f} B in a single-device "
                        "serving program (predicted: none)",
                        kind=kind, actual=nb, predicted=0.0))
            return findings
        for kind, want in sorted(pred.items()):
            got = actual.get(kind, 0.0)
            if abs(got - want) > self.rel_tol * want:
                findings.append(self.finding(
                    prog.name,
                    f"{kind}: {got:.0f} B in HLO vs {want:.0f} B "
                    f"predicted (rel_tol {self.rel_tol})",
                    kind=kind, actual=got, predicted=want))
        for kind, got in sorted(actual.items()):
            if kind not in pred and got >= self.abs_floor:
                findings.append(self.finding(
                    prog.name,
                    f"unbudgeted collective kind {kind}: {got:.0f} B "
                    "(not part of the expert schedule's model)",
                    severity="warning", kind=kind, actual=got))
        return findings

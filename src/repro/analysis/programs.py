"""Trace the engine's real serving programs for the analyzers.

``trace_program(variant)`` builds a ServingEngine exactly like the serving
tests do (reduced config by default — same architecture family, 2 layers),
lowers the production jit for that variant, and packages the compiled HLO
text plus the tree facts every rule needs:

  * which flat entry parameters are cache leaves (R1 names the unaliased
    leaf: with params as argument 0 and cache as argument 1, cache leaf i
    is flat parameter ``n_param_leaves + i`` — XLA only prunes *unused*
    parameters and the weights/cache are always used, which
    ``entry_param_count`` lets R1 verify);
  * cache leaf byte sizes (the copy-size thresholds);
  * QuantTensor data/scale sibling leaf indices (R5's taint seeds);
  * mesh shard counts (R2's prediction inputs).

The five CLI variants: ``decode`` (reference one-token step), ``unified``
(mixed prefill/decode block), ``paged`` (page-pool unified), ``int8``
(unified over the quantized weight store), ``paged_kernel`` (page pool
attended through the Pallas block-table kernel — same program shape as
``paged``, minus the virtual-cache gather R1 lints for).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.quant import QuantTensor
from repro.serving.engine import EngineConfig, ServingEngine

DEFAULT_ARCH = "qwen3_moe_30b_a3b"
VARIANTS = ("decode", "unified", "paged", "int8", "paged_kernel")

_ENTRY_PARAM_RE = re.compile(r"parameter\((\d+)\)")


@dataclasses.dataclass(frozen=True)
class QuantLeaf:
    """One QuantTensor's sibling leaves, as flat jaxpr-invar indices."""
    data_idx: int
    scale_idx: int
    path: str
    full_elems: int      # logical (dequantized) element count


@dataclasses.dataclass
class TracedProgram:
    name: str            # variant name shown in findings
    variant: str
    kind: str            # "decode" | "unified"
    engine: ServingEngine
    cfg: Any
    ecfg: EngineConfig
    hlo_text: str
    cache_paths: list
    cache_bytes: list
    n_param_leaves: int
    donated: bool
    batch: int
    seq: int             # tokens per row per step (1 for decode)
    copy_exact_sizes: bool
    n_exp_shards: int
    n_batch_shards: int
    quant_leaves: list
    _jaxpr_thunk: Callable | None = None
    _jaxpr_cache: Any = None

    @property
    def entry_param_count(self) -> int:
        entry = self.hlo_text[self.hlo_text.index("ENTRY"):]
        return len(set(_ENTRY_PARAM_RE.findall(entry)))

    def jaxpr(self):
        if self._jaxpr_cache is None and self._jaxpr_thunk is not None:
            self._jaxpr_cache = self._jaxpr_thunk()
        return self._jaxpr_cache


def _leaf_bytes(leaves) -> list:
    return [int(np.prod(a.shape)) * a.dtype.itemsize for a in leaves]


def quant_leaf_map(params) -> list:
    """Flat-index map of QuantTensor (data, scale) sibling pairs.

    jax flattens a QuantTensor into (data, scale) in that order, so the
    pairs are adjacent leaves sharing a path prefix; the indices returned
    are positions in ``tree_leaves(params)`` — which equal jaxpr invar
    indices for any jit body taking params as its first argument."""
    flat = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QuantTensor))[0]
    out, idx = [], 0
    for path, leaf in flat:
        if isinstance(leaf, QuantTensor):
            out.append(QuantLeaf(
                data_idx=idx, scale_idx=idx + 1,
                path=jax.tree_util.keystr(path),
                full_elems=int(np.prod(leaf.shape))))
            idx += 2
        else:
            idx += 1
    return out


def _mesh_shards(mesh) -> tuple:
    if mesh is None:
        return 1, 1
    names = getattr(mesh, "axis_names", ())
    n_exp = mesh.shape["model"] if "model" in names else 1
    n_batch = 1
    for a in names:
        if a in ("pod", "data"):
            n_batch *= mesh.shape[a]
    return n_exp, n_batch


def build_engine(variant: str, arch: str = DEFAULT_ARCH, *, donate: bool = True,
                 mesh=None, cfg_kw: dict | None = None,
                 ecfg_kw: dict | None = None) -> ServingEngine:
    """A fresh engine configured for ``variant`` (same shapes the zero-copy
    tests pin: max_batch=2, prefill_len=8, max_cache=32, chunk_len=4)."""
    if variant not in VARIANTS and variant not in ("int4",):
        raise ValueError(f"unknown variant {variant!r}; pick from {VARIANTS}")
    cfg_kw = dict(cfg_kw or {})
    if variant in ("int8", "int4"):
        cfg_kw.setdefault("weight_quant", variant)
    cfg = get_config(arch).reduced().replace(**cfg_kw)
    ekw: dict = dict(max_batch=2, prefill_len=8, max_cache=32,
                     donate_buffers=donate)
    if variant == "decode":
        ekw["unified_step"] = False
    else:
        ekw.update(unified_step=True, chunk_len=4)
    if variant == "paged":
        ekw.update(paged=True, page_size=8)
    if variant == "paged_kernel":
        # page_size 5 / 9-page pool: deliberately OFF the auto pool size
        # (max_batch * max_blocks) so the three buffer families R1 must
        # tell apart — virtual cache (B*NB*ps slots), per-layer pool
        # slice (num_pages*ps slots), MoE dispatch (B*T token rows) —
        # all have distinct byte sizes and exact-size matching of
        # virtual-cache traffic cannot collide (auto pools make slice
        # == virtual ALWAYS, since num_pages = B * max_blocks)
        ekw.update(paged=True, page_size=5, num_pages=9, paged_kernel=True)
    ekw.update(ecfg_kw or {})
    return ServingEngine(cfg, EngineConfig(**ekw), mesh=mesh)


def trace_program(variant: str, arch: str = DEFAULT_ARCH, *,
                  donate: bool = True, mesh=None, cfg_kw: dict | None = None,
                  ecfg_kw: dict | None = None,
                  name: str | None = None) -> TracedProgram:
    """Lower the production jit for ``variant`` and package it for rules."""
    eng = build_engine(variant, arch, donate=donate, mesh=mesh,
                       cfg_kw=cfg_kw, ecfg_kw=ecfg_kw)
    cfg, ecfg = eng.cfg, eng.ecfg
    b = ecfg.max_batch
    ivec = jnp.zeros((b,), jnp.int32)
    bvec = jnp.zeros((b,), bool)
    fvec = jnp.zeros((b,), jnp.float32)
    step = jnp.zeros((), jnp.int32)
    if variant == "decode":
        kind, seq = "decode", 1
        args = (eng.params, eng.cache, ivec, ivec, bvec, fvec, ivec, step)
        lowered = eng._jit_decode.lower(*args, False)
        jaxpr_thunk = lambda: jax.make_jaxpr(
            eng._decode, static_argnums=(8,))(*args, False)
    else:
        kind, seq = "unified", eng.chunk_len
        toks = jnp.zeros((b, eng.chunk_len), jnp.int32)
        bt = (jnp.zeros((b, eng.max_blocks), jnp.int32)
              if eng.paged else None)
        # fvec after topks is the fault-injection poison vector (all-zero
        # = finite = no injection; serving/faults.py)
        args = (eng.params, eng.cache, toks, ivec, ivec, ivec, bt,
                bvec, bvec, fvec, ivec, fvec, step)
        lowered = eng._jit_unified.lower(*args, False)
        jaxpr_thunk = lambda: jax.make_jaxpr(
            eng._unified, static_argnums=(13,))(*args, False)
    txt = lowered.compile().as_text()

    cache_flat = jax.tree_util.tree_flatten_with_path(eng.cache)[0]
    cache_paths = [jax.tree_util.keystr(p) for p, _ in cache_flat]
    cache_leaves = [a for _, a in cache_flat]
    n_exp, n_batch = _mesh_shards(mesh)
    # production MoE configs keep the capacity-free gather decode path on;
    # its selected-expert weight loads legitimately copy buffers larger
    # than a cache leaf, so R1 matches cache-leaf sizes exactly there and
    # uses the stricter >= min-leaf threshold everywhere else (mirrors
    # tests/test_zero_copy.py's two modes)
    exact = bool(cfg.is_moe and getattr(cfg, "gather_decode_max_tk", 0))
    return TracedProgram(
        name=name or variant, variant=variant, kind=kind, engine=eng,
        cfg=cfg, ecfg=ecfg, hlo_text=txt, cache_paths=cache_paths,
        cache_bytes=_leaf_bytes(cache_leaves),
        n_param_leaves=len(jax.tree_util.tree_leaves(eng.params)),
        donated=donate, batch=b, seq=seq, copy_exact_sizes=exact,
        n_exp_shards=n_exp, n_batch_shards=n_batch,
        quant_leaves=quant_leaf_map(eng.params),
        _jaxpr_thunk=jaxpr_thunk)

"""R4 host-sync detector: the hot loop never blocks on the device.

Origin: PR2/PR3 (async dispatch pipeline) — the engine's throughput rests
on the host scheduler running AHEAD of the device: every jit dispatch is
async, generated tokens are read back only at ``_harvest`` boundaries,
and the decode feedback loop stays device-resident (``last_tok``).  One
``.item()`` / ``np.asarray`` / implicit ``bool`` on a device array inside
the scheduling path serializes host and device and the dispatch-bound
soft spot returns.

This is an AST scan of the engine source (no execution): within the
hot-loop methods it tracks which expressions are device-rooted —
``self.last_tok`` / ``self.cache`` / ``self._sample_key`` and any local
assigned from a ``self._jit_*`` call — and flags

  * ``.item()`` on a device-rooted expression;
  * ``np.**(device_rooted)`` / ``jax.device_get(...)`` / builtin
    ``int/float/bool(device_rooted)`` — forced transfers;
  * ``if``/``while`` tests on a device-rooted expression (implicit
    ``__bool__`` blocks);
  * ``.block_until_ready()`` not guarded by an ``async_steps`` check
    (the documented opt-in sync point).

``_harvest`` is the allowed boundary and is not scanned.  PR7 adds a
second documented boundary: ``_quarantine_check`` — the NaN-guard's
per-step finiteness readback (``EngineConfig.nan_guard``), an explicit
opt-in sync exactly like ``async_steps=False`` — and extends the scanned
set with the scheduler's preempt/restore/cancel/growth methods, which
must stay pure host bookkeeping (they run inside the admission path of
every iteration).
"""
from __future__ import annotations

import ast
import inspect
import textwrap

from repro.analysis.framework import Rule

HOT_METHODS = ("step", "_step_unified", "_admit", "_admit_batched",
               "_admit_sequential", "_admit_paged", "_post_admit",
               "_release_slot", "_prefix_insert", "_next_step_idx",
               # PR7 resilience layer: scheduling decisions are host-only
               "_ensure_decode_page", "_preempt_slot", "_running_rows",
               "_covered", "preempt", "cancel", "_terminate_slot",
               "_finish_slot", "_sweep_deadlines", "_quarantine")
DEVICE_ATTRS = ("last_tok", "cache", "_sample_key")
_FORCING_BUILTINS = ("int", "float", "bool")


def _engine_source() -> str:
    from repro.serving import engine
    return inspect.getsource(engine)


class _MethodScan(ast.NodeVisitor):
    def __init__(self, rule, method: str, device_attrs):
        self.rule = rule
        self.method = method
        self.device_attrs = device_attrs
        self.tainted: set = set()
        self.findings: list = []
        self._async_guard_depth = 0

    # -- device-rootedness --------------------------------------------------

    def _rooted(self, node) -> bool:
        if isinstance(node, ast.Attribute):
            if (isinstance(node.value, ast.Name)
                    and node.value.id == "self"
                    and node.attr in self.device_attrs):
                return True
            return self._rooted(node.value)
        if isinstance(node, ast.Subscript):
            return self._rooted(node.value)
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self._rooted(e) for e in node.elts)
        return False

    def _collect_taint(self, fn: ast.FunctionDef):
        # two passes so a = jit(...); b = a chains resolve
        for _ in range(2):
            for node in ast.walk(fn):
                if not isinstance(node, ast.Assign):
                    continue
                val = node.value
                from_jit = (isinstance(val, ast.Call)
                            and isinstance(val.func, ast.Attribute)
                            and val.func.attr.startswith("_jit_"))
                if not (from_jit or self._rooted(val)):
                    continue
                for tgt in node.targets:
                    for el in ([tgt] if not isinstance(tgt, ast.Tuple)
                               else tgt.elts):
                        if isinstance(el, ast.Name):
                            self.tainted.add(el.id)

    # -- violations ---------------------------------------------------------

    def _flag(self, node, what: str):
        self.findings.append(self.rule.finding(
            f"engine.{self.method}",
            f"{what} at line {node.lineno} — blocking device->host sync "
            "in the hot loop (only _harvest may read back)",
            method=self.method, line=node.lineno, what=what))

    def visit_Call(self, node: ast.Call):
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr == "item" and self._rooted(f.value):
                self._flag(node, ".item() on a device array")
            elif f.attr == "block_until_ready":
                if self._async_guard_depth == 0:
                    self._flag(node, ".block_until_ready() outside an "
                                     "async_steps guard")
            elif (isinstance(f.value, ast.Name) and f.value.id == "np"
                  and any(self._rooted(a) for a in node.args)):
                self._flag(node, f"np.{f.attr}() on a device array")
            elif (f.attr == "device_get"
                  and isinstance(f.value, ast.Name)
                  and f.value.id == "jax"):
                self._flag(node, "jax.device_get()")
        elif (isinstance(f, ast.Name) and f.id in _FORCING_BUILTINS
              and any(self._rooted(a) for a in node.args)):
            self._flag(node, f"{f.id}() on a device array")
        self.generic_visit(node)

    def _visit_test(self, node):
        if self._rooted(node.test):
            self._flag(node, "implicit bool() of a device array in a "
                             "branch test")

    def visit_If(self, node: ast.If):
        self._visit_test(node)
        guarded = "async_steps" in ast.dump(node.test)
        if guarded:
            self._async_guard_depth += 1
        for child in node.body:
            self.visit(child)
        if guarded:
            self._async_guard_depth -= 1
        for child in node.orelse:
            self.visit(child)
        self.visit(node.test)

    def visit_While(self, node: ast.While):
        self._visit_test(node)
        self.generic_visit(node)


class HostSyncRule(Rule):
    rule_id = "R4"
    name = "host-sync"
    description = ("no blocking device->host reads in hot-loop methods "
                   "outside harvest boundaries")
    requires = "source"

    def __init__(self, methods=HOT_METHODS, device_attrs=DEVICE_ATTRS):
        self.methods = methods
        self.device_attrs = device_attrs

    def check_source(self, source: str | None = None,
                     program: str = "serving/engine.py") -> list:
        tree = ast.parse(textwrap.dedent(source if source is not None
                                         else _engine_source()))
        findings = []
        for node in ast.walk(tree):
            if (isinstance(node, ast.FunctionDef)
                    and node.name in self.methods):
                scan = _MethodScan(self, node.name, self.device_attrs)
                scan.method = node.name
                scan._collect_taint(node)
                for stmt in node.body:
                    scan.visit(stmt)
                for f in scan.findings:
                    findings.append(f)
        return findings

"""R1 donation-alias lint: the zero-copy cache invariant, as a rule.

Origin: PR2 (donated decode step), PR3 (unified block), PR4 (page pool).
The paper's C1 finding is that hidden memory management — a full cache
copy per step — dominates Apple-stack inference; our engine donates the
cache operand of every jit and updates it in place, so the compiled
program must (a) alias every donated cache leaf to an output in the
module's ``input_output_alias`` header and (b) contain no copy the size
of a cache leaf, *including async copy-start/copy-done pairs*.

Leaf naming: params is argument 0 and the cache argument 1 of every jit
body, so cache leaf i is flat entry parameter ``n_param_leaves + i`` (XLA
prunes only unused trailing scalars, never the used weight/cache prefix —
``TracedProgram.entry_param_count`` would drop below
``n_param_leaves + n_cache`` if that assumption ever broke, which this
rule reports as its own finding instead of guessing).

PR8 extends the invariant to the paged-attention kernel variant
(``EngineConfig.paged_kernel``): beyond the alias/copy checks, the
compiled program must contain no GATHER materializing a row-batch
virtual cache — the (B, NB*page_size, Hkv, hd) buffer the reference
paged path builds per pool leaf, which the Pallas block-table kernel
exists to remove.  ``virtual_cache_traffic`` is the detector; the
gather-path program provably trips it (tests/test_hlo_analysis.py uses
it as the tripwire baseline, the same pattern as the undonated-baseline
test).
"""
from __future__ import annotations

from repro.analysis.framework import Rule
from repro.launch import hlo


def virtual_cache_sizes(prog) -> set:
    """Per-(layer, leaf) virtual-cache byte sizes for a paged program: the
    (B, NB*page_size, Hkv, ·) buffer the gather path materializes from a
    pool leaf of (L, P, page_size, Hkv, ·).  Exact sizes, because MoE
    expert-weight gathers are legitimately pool-scale and a >= threshold
    would flag them."""
    eng = prog.engine
    n_layers = prog.cfg.num_layers
    scale = prog.batch * eng.max_blocks
    return {nb // (n_layers * eng.num_pages) * scale
            for nb in prog.cache_bytes}


def virtual_cache_traffic(prog) -> list:
    """Every gather or copy in ``prog`` whose result is exactly a
    virtual-cache buffer, as (kind, line, bytes)."""
    sizes = virtual_cache_sizes(prog)
    lo = min(sizes)
    out = [("gather", line, nb)
           for line, nb in hlo.sized_gathers(prog.hlo_text, lo)
           if nb in sizes]
    out += [("copy", line, nb)
            for line, nb in hlo.sized_copies(prog.hlo_text, lo)
            if nb in sizes]
    return out


class DonationAliasRule(Rule):
    rule_id = "R1"
    name = "donation-alias"
    description = ("every donated cache leaf aliases an output; no copy "
                   "(sync or async) of cache-leaf size")
    requires = "hlo"

    def check(self, prog):
        findings = []
        txt = prog.hlo_text
        n_cache = len(prog.cache_bytes)
        if prog.entry_param_count < prog.n_param_leaves + n_cache:
            findings.append(self.finding(
                prog.name,
                "entry parameter count %d < params+cache leaves %d — flat "
                "alias numbering unverifiable (a weight or cache leaf was "
                "pruned)" % (prog.entry_param_count,
                             prog.n_param_leaves + n_cache)))
            return findings
        aliased = {p.param_number for p in hlo.input_output_alias_pairs(txt)}
        for i, (path, nb) in enumerate(zip(prog.cache_paths,
                                           prog.cache_bytes)):
            pnum = prog.n_param_leaves + i
            if pnum not in aliased:
                findings.append(self.finding(
                    prog.name,
                    f"cache leaf {path} ({nb} B, entry parameter {pnum}) "
                    "is not aliased to any output — XLA will materialize "
                    "a fresh buffer every step (paper C1 overhead)",
                    leaf=path, bytes=nb, param_number=pnum))
        min_leaf = min(prog.cache_bytes)
        copies = hlo.sized_copies(txt, min_leaf)
        if prog.copy_exact_sizes:
            # gather-path weight loads legitimately exceed the smallest
            # cache leaf: only a copy of a cache leaf's EXACT size is the
            # cache materializing (mirrors the production-config zero-copy
            # tests)
            sizes = set(prog.cache_bytes)
            copies = [c for c in copies if c[1] in sizes]
        for line, nb in copies:
            findings.append(self.finding(
                prog.name,
                f"cache-sized copy ({nb} B): {line[:120]}",
                bytes=nb, line=line))
        if getattr(prog.ecfg, "paged_kernel", False):
            # the kernel variant's reason to exist: no virtual-cache
            # materialization — neither as a gather (the reference path's
            # page indirection) nor as a copy of the gathered buffer
            for kind, line, nb in virtual_cache_traffic(prog):
                findings.append(self.finding(
                    prog.name,
                    f"virtual-cache-sized {kind} ({nb} B) in the "
                    f"paged_kernel program — attention is materializing "
                    f"the (B, NB*page_size, Hkv, ·) buffer the Pallas "
                    f"kernel must avoid: {line[:120]}",
                    bytes=nb, line=line))
        return findings

"""R6 sharding lint: expert-sharded weights never travel.

Origin: PR1 / docs/DESIGN.md §5 — every schedule moves ACTIVATIONS
between expert shards; the expert weights themselves stay put (that is
the entire point of expert parallelism, and the paper's Table 2 memory
budget depends on it).  A resharding regression — a PartitionSpec typo,
a schedule accidentally closing over replicated weights — shows up in
HLO as an ``all-gather`` whose result is a full expert-weight slice.

The rule flags any all-gather whose gathered result reaches one layer's
expert-weight slice (the smallest expert leaf divided by its leading
stacked-layer dim — per-layer gathers inside the scan body are what a
bad spec produces).  Activation gathers (centralized comm 1) are orders
of magnitude below that threshold.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.analysis.framework import Rule
from repro.launch import hlo


def expert_gather_threshold(prog) -> int | None:
    """Smallest per-layer expert-weight slice in bytes (None: no experts)."""
    flat = jax.tree_util.tree_flatten_with_path(prog.engine.params)[0]
    sizes = []
    n_layers = max(int(getattr(prog.cfg, "num_layers", 1)), 1)
    for path, leaf in flat:
        if "experts" not in jax.tree_util.keystr(path):
            continue
        nb = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        # stacked leaves are (L, E, ...): a per-layer gather moves nb / L
        sizes.append(nb // n_layers if leaf.ndim >= 3 else nb)
    return min(sizes) if sizes else None


class ShardingLintRule(Rule):
    rule_id = "R6"
    name = "sharding-lint"
    description = "no all-gather of expert-sharded weight leaves"
    requires = "hlo"

    def check(self, prog):
        threshold = expert_gather_threshold(prog)
        if threshold is None:
            return []
        findings = []
        for kind, nb, line in hlo.collective_ops(prog.hlo_text):
            if kind == "all-gather" and nb >= threshold:
                findings.append(self.finding(
                    prog.name,
                    f"all-gather of {nb} B >= expert-weight slice "
                    f"({threshold} B) — expert weights must stay sharded: "
                    f"{line[:120]}",
                    bytes=nb, threshold=threshold, line=line))
        return findings

"""R5 QuantTensor integrity: data and scale stay married.

Origin: PR5 (blockwise quantized weight store, docs/DESIGN.md §8).  A
QuantTensor is a pair of sibling pytree leaves — an int8/packed-int4
payload and its per-block fp32 scales — and correctness rests on two
dataflow facts the type system cannot see once jax flattens the tree:

  1. every matmul that consumes the payload also consumes its OWN scale
     (a detached or swapped scale silently rescales the weights);
  2. the full dequantized weight is never materialized outside the
     ``qdot`` policy point (materializing it re-spends the memory the
     store exists to save — paper Table 2's budget).

Both are checked by taint propagation over the jaxpr: each payload leaf
seeds token ``("d", i)``, each scale ``("s", i)``; taints flow through
every equation (recursing into scan/while/cond/pjit sub-jaxprs, with a
fixpoint for loop carries).  At each ``dot_general`` an operand tainted
by ``d_i`` must also carry ``s_i``.  A float output reaching leaf i's
full logical element count while tainted by ``d_i`` is a full
dequantized materialization; it is allowed only when every consumer is
the dequant->dot chain itself (dot_general, or the mul/convert/
transpose/reshape glue inside qdot) — a scan, slice, add or store
consuming it means the weight was materialized for general use.
"""
from __future__ import annotations

import jax

from repro.analysis.framework import Rule

try:  # jax >= 0.4.x keeps these importable from jax.core
    from jax.core import ClosedJaxpr, Literal
except ImportError:  # pragma: no cover
    from jax.extend.core import ClosedJaxpr, Literal  # type: ignore

# eqn kinds a full-size dequantized float may legally feed: the qdot
# dequant chain (convert -> mul by repeated scales -> [layout] -> dot)
_QDOT_CONSUMERS = frozenset(
    {"dot_general", "mul", "convert_element_type", "transpose", "reshape"})

_SUBJAXPR_CALLS = ("pjit", "closed_call", "core_call", "remat", "checkpoint",
                   "custom_jvp_call", "custom_vjp_call", "remat_call",
                   "named_call")


def _sub_closed(eqn):
    sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
    if sub is None:
        return None
    return sub if isinstance(sub, ClosedJaxpr) else ClosedJaxpr(sub, ())


class _Walker:
    def __init__(self, quant_leaves, emit):
        self.leaves = {q.data_idx: q for q in quant_leaves}
        self.emit = emit          # (check_key, message_kw) -> None, deduped
        self._seen = set()

    def _report(self, key, **kw):
        if key not in self._seen:
            self._seen.add(key)
            self.emit(key, kw)

    # -- checks -------------------------------------------------------------

    def _check_dot(self, eqn, in_taints):
        for t in in_taints:
            scales = {i for kind, i in t if kind == "s"}
            for kind, i in t:
                if kind == "d" and i not in scales:
                    q = self.leaves[i]
                    self._report(("detached", i), leaf=q.path,
                                 reason="dot_general operand tainted by "
                                        f"{q.path}.data without its .scale")

    def _check_materialization(self, jaxpr, env):
        # consumers within this scope only: a full-size float flowing into
        # a scan/slice/store here is the violation even if the sub-jaxpr
        # then slices it finely
        consumers: dict = {}
        for eqn in jaxpr.eqns:
            for a in eqn.invars:
                if not isinstance(a, Literal):
                    consumers.setdefault(a, []).append(eqn.primitive.name)
        for var, taint in env.items():
            aval = getattr(var, "aval", None)
            if aval is None or not hasattr(aval, "dtype"):
                continue
            try:
                import numpy as np
                is_float = np.issubdtype(aval.dtype, np.inexact)
                size = int(np.prod(aval.shape)) if aval.shape else 1
            except Exception:  # abstract tokens etc.
                continue
            if not is_float:
                continue
            for kind, i in taint:
                if kind != "d":
                    continue
                q = self.leaves[i]
                if size < q.full_elems:
                    continue
                bad = [c for c in consumers.get(var, ())
                       if c not in _QDOT_CONSUMERS]
                if bad:
                    self._report(
                        ("materialized", i), leaf=q.path,
                        reason=f"full dequantized weight ({size} elems >= "
                               f"{q.full_elems}) of {q.path} consumed by "
                               f"{sorted(set(bad))} — outside the qdot "
                               "policy point")

    # -- propagation --------------------------------------------------------

    def walk(self, jaxpr, in_taints):
        """Forward taint pass over one (sub)jaxpr; returns outvar taints."""
        env: dict = {}

        def read(atom):
            return frozenset() if isinstance(atom, Literal) \
                else env.get(atom, frozenset())

        for v, t in zip(jaxpr.invars, in_taints):
            env[v] = frozenset(t)
        for v in jaxpr.constvars:
            env[v] = frozenset()

        for eqn in jaxpr.eqns:
            taints = [read(a) for a in eqn.invars]
            prim = eqn.primitive.name
            if prim == "dot_general":
                self._check_dot(eqn, taints)
            if prim == "scan":
                nc = eqn.params["num_consts"]
                ncar = eqn.params["num_carry"]
                body = eqn.params["jaxpr"].jaxpr
                consts, carry = taints[:nc], list(taints[nc:nc + ncar])
                xs = taints[nc + ncar:]
                while True:  # fixpoint over the loop carry
                    outs = self.walk(body, consts + carry + xs)
                    grown = [c | o for c, o in zip(carry, outs[:ncar])]
                    if grown == carry:
                        break
                    carry = grown
                for v, t in zip(eqn.outvars, outs):
                    env[v] = t
                continue
            if prim == "while":
                cn = eqn.params["cond_nconsts"]
                bn = eqn.params["body_nconsts"]
                cond = eqn.params["cond_jaxpr"].jaxpr
                body = eqn.params["body_jaxpr"].jaxpr
                cconsts = taints[:cn]
                bconsts = taints[cn:cn + bn]
                carry = list(taints[cn + bn:])
                while True:
                    self.walk(cond, cconsts + carry)
                    outs = self.walk(body, bconsts + carry)
                    grown = [c | o for c, o in zip(carry, outs)]
                    if grown == carry:
                        break
                    carry = grown
                for v, t in zip(eqn.outvars, carry):
                    env[v] = t
                continue
            if prim == "cond":
                branches = eqn.params["branches"]
                ops = taints[1:]  # invars = [pred] + operands
                outs = None
                for br in branches:
                    bouts = self.walk(br.jaxpr, ops)
                    outs = bouts if outs is None else \
                        [a | b for a, b in zip(outs, bouts)]
                for v, t in zip(eqn.outvars, outs or []):
                    env[v] = t
                continue
            if prim in _SUBJAXPR_CALLS:
                sub = _sub_closed(eqn)
                if sub is not None:
                    outs = self.walk(sub.jaxpr, taints)
                    for v, t in zip(eqn.outvars, outs):
                        env[v] = t
                    continue
            union = frozenset().union(*taints) if taints else frozenset()
            for v in eqn.outvars:
                env[v] = union

        self._check_materialization(jaxpr, env)
        return [read(v) for v in jaxpr.outvars]


def check_closed_jaxpr(closed, quant_leaves, emit):
    """Seed invar taints from the quant leaf map and run the walker."""
    n = len(closed.jaxpr.invars)
    seeds = [frozenset() for _ in range(n)]
    for q in quant_leaves:
        if q.data_idx < n:
            seeds[q.data_idx] = frozenset({("d", q.data_idx)})
        if q.scale_idx < n:
            seeds[q.scale_idx] = frozenset({("s", q.data_idx)})
    _Walker(quant_leaves, emit).walk(closed.jaxpr, seeds)


class QuantIntegrityRule(Rule):
    rule_id = "R5"
    name = "quant-integrity"
    description = ("data/scale siblings enter matmuls together; no full "
                   "dequantized weight outside qdot")
    requires = "jaxpr"

    def check(self, prog):
        if not prog.quant_leaves:
            return []
        findings = []

        def emit(key, kw):
            findings.append(self.finding(prog.name, kw.pop("reason"), **kw))

        check_closed_jaxpr(prog.jaxpr(), prog.quant_leaves, emit)
        return findings

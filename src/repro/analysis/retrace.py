"""R3 retrace sanitizer: the engine compiles its documented set, nothing more.

Origin: PR3 (unified scheduler) and the BENCH_serving dispatch-bound soft
spot — on CPU-class backends a silent retrace costs more than hundreds of
steps, and the classic regressions (a host int leaking into a traced
shape, a static flag toggling per step, ragged chunk widths) all manifest
as trace counts creeping past the documented set.

``ServingEngine.trace_counts`` increments at TRACE time inside each jit
body; the documented steady-state budget per engine mode:

  * unified:   2 traces of ``unified`` — the chunk_len-wide mixed block
               and the width-1 pure-decode block (1 when chunk_len == 1);
  * paged:     + 1 ``copy_pages`` (copy-on-write helper);
  * reference: 1 prefill (batched or per-slot) + 1 ``decode``;
  * sampling:  first stochastic request flips the static flag and doubles
               each budget (the one documented retrace).

``drive_engine`` pushes an engine through admission / chunked-prefill /
decode transitions (the transitions that historically retraced); the rule
then compares counts against the budget.  Budgets are upper bounds — a
workload that never hits pure decode traces less, which is fine.

PR7 extends the driven transitions, NOT the budgets: preemption (a
running row evicted to the prefix tree and restored through admission),
restore's partial-tail re-prefill, and NaN-quarantine retry all must
ride the already-compiled programs — restore re-prefills through the
same chunk_len-wide block, the tail copy-on-write reuses the budgeted
``copy_pages`` trace, and a quarantined row's re-dispatch is the
identical shape it failed at.  A scheduler change that sneaks a new
shape into any of those paths now fails R3.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.framework import Rule


def expected_trace_budget(eng) -> dict:
    """Max traces per jit body for this engine's configuration.
    Preempt/restore/quarantine transitions are deliberately NOT budget
    lines: they must reuse the steady-state programs."""
    if getattr(eng, "unified", False):
        budget = {"unified": 2 if eng.chunk_len > 1 else 1}
        if getattr(eng, "paged", False):
            budget["copy_pages"] = 1
    else:
        key = ("prefill_batch" if eng.ecfg.batched_prefill
               else "prefill_one")
        budget = {key: 1, "decode": 1}
    mult = 2 if getattr(eng, "_sampling", False) else 1
    return {k: v * mult for k, v in budget.items()}


def drive_engine(eng, *, rounds: int = 2, prompt_len: int = 6,
                 new_tokens: int = 4, seed: int = 0) -> None:
    """Admission -> chunked prefill -> mixed -> pure-decode transitions,
    twice over, so any shape-dependent retrace has every chance to fire.
    On paged engines, also push a mid-decode preempt + prefix restore; on
    unified engines, a NaN-quarantine retry — both must stay inside the
    steady-state budget (no lines are added for them)."""
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        for _ in range(eng.ecfg.max_batch):
            eng.submit(rng.integers(0, 50, prompt_len),
                       max_new_tokens=new_tokens)
        eng.run_until_done()
    if getattr(eng, "paged", False):
        # preempt a decoding row, then restore through the prefix cache:
        # the block-table remap + one-token tail re-prefill must reuse the
        # chunk_len-wide block and the budgeted copy_pages CoW trace.
        uid = eng.submit(rng.integers(0, 50, prompt_len),
                         max_new_tokens=new_tokens + 2)
        req = eng._all[uid]
        for _ in range(64):
            eng.step()
            slot = next((i for i, r in enumerate(eng.slots) if r is req),
                        None)
            if (slot is not None
                    and eng.prefill_pos[slot] >= len(eng.slot_ctx[slot])):
                break
        eng.preempt(uid)
        eng.run_until_done()
    if getattr(eng, "unified", False) and eng.faults is None:
        # quarantine retry: poison one step's logits; the retried dispatch
        # is the identical shape it failed at — zero extra traces.
        from repro.serving.faults import Fault, FaultPlan
        guard_was = eng._guard
        eng.faults = FaultPlan([Fault(eng._iter + 2, "nan")])
        eng._guard = True
        try:
            eng.submit(rng.integers(0, 50, prompt_len),
                       max_new_tokens=new_tokens)
            eng.run_until_done()
        finally:
            eng.faults = None
            eng._guard = guard_was


class RetraceRule(Rule):
    rule_id = "R3"
    name = "retrace"
    description = "no jit retrace beyond the documented set"
    requires = "engine"

    def __init__(self, workload=drive_engine):
        self.workload = workload

    def check_engine(self, eng, program: str = "engine") -> list:
        """Drive ``eng`` (must be freshly built: trace_counts at zero —
        note .lower() also traces) and audit its trace counts."""
        if self.workload is not None:
            self.workload(eng)
        budget = expected_trace_budget(eng)
        findings = []
        for key, count in sorted(eng.trace_counts.items()):
            allowed = budget.get(key, 0)
            if count > allowed:
                findings.append(self.finding(
                    program,
                    f"jit body '{key}' traced {count}x (documented budget "
                    f"{allowed}) — a silent recompile is eating dispatch "
                    "latency",
                    body=key, count=count, budget=allowed))
        return findings

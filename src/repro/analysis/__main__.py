"""CLI driver: ``python -m repro.analysis``.

Traces the engine's serving programs (decode / unified / paged / int8 by
default, reduced config so it runs on any CPU), runs every rule, prints
the report, and exits nonzero on any error-severity finding — the CI
``analysis`` job gates on exactly this.

  python -m repro.analysis                          # all rules, all programs
  python -m repro.analysis --programs decode,int8 --rules R1,R5
  python -m repro.analysis --warn-only R2 --json report.json
  python -m repro.analysis --ep 4 --data 2          # trace on a host mesh

``--ep``/``--data`` fake a (data, model) device mesh via
``--xla_force_host_platform_device_count`` (must run before jax loads, so
this module sets XLA_FLAGS before importing anything jax-backed).
"""
from __future__ import annotations

import argparse
import os
import sys


def _parse_args(argv=None):
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis of the engine's serving programs")
    p.add_argument("--arch", default="qwen3_moe_30b_a3b")
    p.add_argument("--programs",
                   default="decode,unified,paged,int8,paged_kernel",
                   help="comma list of "
                        "decode,unified,paged,int8,paged_kernel")
    p.add_argument("--rules", default="R1,R2,R3,R4,R5,R6",
                   help="comma list of rule ids to run")
    p.add_argument("--warn-only", default="",
                   help="comma list of rule ids demoted to warnings")
    p.add_argument("--json", dest="json_path", default="",
                   help="write the machine-readable report here "
                        "('-' for stdout)")
    p.add_argument("--ep", type=int, default=1,
                   help="expert (model) shards on a faked host mesh")
    p.add_argument("--data", type=int, default=1,
                   help="data shards on the faked host mesh")
    return p.parse_args(argv)


def main(argv=None) -> int:
    args = _parse_args(argv)
    n_dev = max(args.ep, 1) * max(args.data, 1)
    if n_dev > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n_dev}").strip()

    # jax-backed imports AFTER the device-count env var is pinned
    from repro.analysis import programs as programs_lib
    from repro.analysis.collectives import CollectiveBudgetRule
    from repro.analysis.donation import DonationAliasRule
    from repro.analysis.framework import demote_findings, run_rules
    from repro.analysis.hostsync import HostSyncRule
    from repro.analysis.quant_integrity import QuantIntegrityRule
    from repro.analysis.retrace import RetraceRule
    from repro.analysis.sharding_lint import ShardingLintRule

    rule_ids = [r.strip().upper() for r in args.rules.split(",") if r.strip()]
    warn_only = {r.strip().upper()
                 for r in args.warn_only.split(",") if r.strip()}
    variants = [v.strip() for v in args.programs.split(",") if v.strip()]
    if n_dev > 1 and "paged_kernel" in variants:
        # the Pallas paged-attention path is single-host by contract
        # (serving keeps the gather path under GSPMD; docs/DESIGN.md §11)
        print("skipping paged_kernel on a mesh (single-host variant)")
        variants = [v for v in variants if v != "paged_kernel"]

    mesh = None
    if n_dev > 1:
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(max(args.data, 1), max(args.ep, 1))
    # the multi-device engine path needs an EP-shardable capacity and an
    # unsharded KV cache on the tiny reduced config (same overrides the
    # distributed integration tests use)
    cfg_kw = (dict(capacity_factor=8.0, kv_cache_shard="none")
              if mesh is not None else None)

    prog_rules = [r for r in (DonationAliasRule(), CollectiveBudgetRule(),
                              QuantIntegrityRule(), ShardingLintRule())
                  if r.rule_id in rule_ids]
    print(f"tracing programs: {', '.join(variants)} "
          f"(arch {args.arch}{', mesh ' + str(n_dev) + ' dev' if mesh else ''})",
          flush=True)
    traced = [programs_lib.trace_program(v, args.arch, mesh=mesh,
                                         cfg_kw=cfg_kw)
              for v in variants]
    report = run_rules(prog_rules, traced, warn_only=warn_only)
    report.rules = rule_ids

    if "R3" in rule_ids:
        retrace = RetraceRule()
        kinds = [k for k, wanted in (
            ("unified", any(v in variants
                            for v in ("unified", "paged", "int8",
                                      "paged_kernel"))),
            ("decode", "decode" in variants)) if wanted]
        for variant in kinds:
            eng = programs_lib.build_engine(variant, args.arch, mesh=mesh,
                                            cfg_kw=cfg_kw)
            report.findings.extend(demote_findings(
                retrace.check_engine(eng, program=f"{variant}-engine"),
                warn_only))
    if "R4" in rule_ids:
        report.findings.extend(demote_findings(
            HostSyncRule().check_source(), warn_only))

    print(report.summary())
    if args.json_path == "-":
        print(report.to_json())
    elif args.json_path:
        with open(args.json_path, "w") as fh:
            fh.write(report.to_json())
        print(f"report written to {args.json_path}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())

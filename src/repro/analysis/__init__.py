"""Rule-based static analysis over the engine's compiled programs.

The paper's central finding is that memory-management overhead (hidden
cache copies) and per-message latency — not link bandwidth — dominate
multi-node MoE inference.  PR2–PR5 encoded the corresponding invariants
(donated zero-copy caches, bounded collective bytes, device-only routing,
QuantTensor sibling-leaf integrity) as ad-hoc regex pins; this package
turns them into named, CI-gated rules over two front-ends:

  * compiled HLO text (``launch/hlo.py`` parser) — what XLA actually
    scheduled, including async ``copy-start`` pairs and collectives;
  * jaxpr traversal (``jax.make_jaxpr``) — dataflow facts such as which
    matmuls a quantized leaf reaches, before XLA rewrites them away.

Rules (see docs/DESIGN.md §9):
  R1 donation-alias   every donated cache leaf aliases an output; no copy
                      (sync or async) the size of a cache leaf
  R2 collective-bytes per-kind collective bytes match core/perf_model
  R3 retrace          engine traces stay within the documented set
  R4 host-sync        no blocking device->host reads in the hot loop
  R5 quant-integrity  data/scale siblings enter matmuls together; no
                      full-weight dequantized materialization
  R6 sharding-lint    no all-gather of expert-sharded weight leaves

Run ``python -m repro.analysis`` for the CLI driver.
"""
from repro.analysis.framework import Finding, Report, Rule, run_rules  # noqa: F401

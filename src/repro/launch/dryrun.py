import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh without allocating a single real buffer.

For each pair this proves: the sharding config is coherent (no mismatched
collectives), the program fits per-device HBM (memory_analysis), and it
yields the roofline inputs (FLOPs / bytes / collective bytes with
while-loop trip multipliers via launch/hlo.py).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out results/

The 512 placeholder host devices are forced by the XLA_FLAGS line ABOVE ANY
IMPORT — smoke tests and benches never import this module.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import ARCH_IDS, SHAPES, get_config, input_specs
from repro.launch import hlo as hlo_lib
from repro.launch import sharding
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link


def abstract_params(model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def make_train_step(model, mesh, ocfg=None):
    ocfg = ocfg or optim.OptimizerConfig()

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch, mesh)
        params, opt_state, om = optim.update(ocfg, grads, opt_state, params)
        return params, opt_state, {**metrics, **om, "loss": loss}

    return train_step


def make_prefill_step(model, mesh):
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache, mesh)
    return prefill_step


def make_serve_step(model, mesh, context_len):
    def serve_step(params, cache, batch):
        return model.decode_step(params, cache, batch, mesh,
                                 context_len=context_len)
    return serve_step


def lower_pair(arch: str, shape_name: str, mesh, cfg_overrides=None):
    """Lower + compile one (arch, shape) on ``mesh``. Returns (compiled, cfg)."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    p_sds = abstract_params(model)
    batch_sds = input_specs(cfg, shape)

    p_spec = sharding.params_pspec(
        cfg, mesh, p_sds, mode="train" if shape.kind == "train" else "serve")
    b_spec = sharding.batch_pspec(cfg, mesh, batch_sds)
    n_p = sharding.named(mesh, p_spec)
    n_b = sharding.named(mesh, b_spec)

    with mesh:
        if shape.kind == "train":
            o_sds = jax.eval_shape(optim.init, p_sds)
            o_spec = sharding.opt_pspec(cfg, mesh, o_sds, p_spec)
            n_o = sharding.named(mesh, o_spec)
            fn = jax.jit(make_train_step(model, mesh),
                         in_shardings=(n_p, n_o, n_b),
                         out_shardings=(n_p, n_o, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(p_sds, o_sds, batch_sds)
        elif shape.kind == "prefill":
            c_sds = model.cache_specs(shape.global_batch, shape.seq_len)
            c_spec = sharding.cache_pspec(cfg, mesh, c_sds)
            n_c = sharding.named(mesh, c_spec)
            fn = jax.jit(make_prefill_step(model, mesh),
                         in_shardings=(n_p, n_b, n_c),
                         out_shardings=(None, n_c),
                         donate_argnums=(2,))
            lowered = fn.lower(p_sds, batch_sds, c_sds)
        else:  # decode
            c_sds = model.cache_specs(shape.global_batch, shape.seq_len)
            c_spec = sharding.cache_pspec(cfg, mesh, c_sds)
            n_c = sharding.named(mesh, c_spec)
            fn = jax.jit(make_serve_step(model, mesh, shape.seq_len),
                         in_shardings=(n_p, n_c, n_b),
                         out_shardings=(None, n_c),
                         donate_argnums=(1,))
            lowered = fn.lower(p_sds, c_sds, batch_sds)
        compiled = lowered.compile()
    return compiled, cfg


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train, 2·N·D per decoded/prefilled token
    (N = active params for MoE)."""
    n = cfg.n_active_params() if cfg.is_moe else cfg.n_params()
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind in ("train", "prefill") else 1)
    return (6.0 if shape.kind == "train" else 2.0) * n * tokens


def roofline(totals: hlo_lib.Totals, n_devices: int, cfg, shape) -> dict:
    """Three roofline terms (seconds). HLO numbers are per-device, so terms
    are per-device time = total work / (chips × per-chip rate).  Memory uses
    convert-adjusted bytes (the CPU backend's bf16->f32 upcasts don't exist
    on TPU)."""
    t_comp = totals.flops / PEAK_FLOPS
    t_mem = totals.hbm_bytes / HBM_BW
    t_coll = totals.collective_bytes / ICI_BW
    dom = max(("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
              key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    return {
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_per_device": totals.flops,
        "useful_flop_ratio": mf / max(totals.flops * n_devices, 1.0),
        "collective_by_kind": dict(totals.coll),
    }


_UPCAST_RE = None


def cpu_upcast_bytes(hlo_text: str) -> float:
    """Bytes of large f32 buffers produced by ``convert`` of bf16 stacks —
    the CPU backend's whole-array upcasts (>=32 MiB) that a TPU build would
    not allocate.  Used to adjust the peak-memory estimate."""
    import re as _re
    global _UPCAST_RE
    if _UPCAST_RE is None:
        _UPCAST_RE = _re.compile(r"= f32\[([0-9,]+)\][^=]*\bconvert\(")
    total = 0.0
    for m in _UPCAST_RE.finditer(hlo_text):
        n = 1
        for d in m.group(1).split(","):
            n *= int(d)
        if n * 4 >= 32 * 2**20:
            total += n * 4
    return total


def run_pair(arch: str, shape_name: str, multi_pod: bool,
             cfg_overrides=None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(str(s) for s in mesh.devices.shape),
           "n_devices": n_dev, "ok": False}
    try:
        compiled, cfg = lower_pair(arch, shape_name, mesh, cfg_overrides)
        ma = compiled.memory_analysis()
        hlo_text = compiled.as_text()
        totals = hlo_lib.analyze(hlo_text)
        upcast = cpu_upcast_bytes(hlo_text)
        rec.update({
            "ok": True,
            "compile_s": round(time.time() - t0, 1),
            "memory": {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                # memory_analysis reports the per-device SPMD program
                "peak_per_device": int(ma.argument_size_in_bytes
                                       + ma.temp_size_in_bytes
                                       + ma.output_size_in_bytes
                                       - ma.alias_size_in_bytes),
                # f32 copies of bf16 stacks made by the CPU backend's upcast
                # pass (hoisted out of the layer loop) — absent on TPU
                "cpu_upcast_bytes": int(upcast),
                # clamped below by live arguments + outputs (converts of
                # freed buffers would otherwise over-subtract)
                "peak_per_device_tpu_est": int(max(
                    ma.argument_size_in_bytes + ma.temp_size_in_bytes
                    + ma.output_size_in_bytes - ma.alias_size_in_bytes
                    - upcast,
                    ma.argument_size_in_bytes + ma.output_size_in_bytes
                    - ma.alias_size_in_bytes)),
            },
            "hlo": {
                "flops_per_device": totals.flops,
                "bytes_per_device_raw": totals.bytes,
                "hbm_bytes_per_device": totals.hbm_bytes,
                "convert_bytes_per_device": totals.convert_bytes,
                "collective_bytes_per_device": totals.collective_bytes,
                "collective_by_kind": dict(totals.coll),
            },
            "roofline": roofline(totals, n_dev, cfg, SHAPES[shape_name]),
        })
        try:
            ca = compiled.cost_analysis()
            rec["xla_cost_analysis"] = {
                "flops_body_once": float(ca.get("flops", -1.0)),
                "bytes_body_once": float(ca.get("bytes accessed", -1.0)),
            }
        except Exception:
            pass
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig overrides (perf exps)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    overrides = json.loads(args.override) if args.override else None
    pairs = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            pairs.append((a, s))

    os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for arch, shape_name in pairs:
        rec = run_pair(arch, shape_name, args.multi_pod, overrides)
        tag = ("-" + args.tag) if args.tag else ""
        fname = f"{arch.replace('-', '_')}_{shape_name}_{rec['mesh']}{tag}.json"
        with open(os.path.join(args.out, fname), "w") as f:
            json.dump(rec, f, indent=1)
        if rec["ok"]:
            r = rec["roofline"]
            print(f"OK   {arch:24s} {shape_name:12s} {rec['mesh']:10s} "
                  f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
                  f"coll={r['collective_s']:.2e}s dom={r['dominant']:10s} "
                  f"peak/dev={rec['memory']['peak_per_device_tpu_est']/2**30:.2f}GiB"
                  f"(raw {rec['memory']['peak_per_device']/2**30:.1f}) "
                  f"[{rec['compile_s']}s]", flush=True)
        else:
            n_fail += 1
            print(f"FAIL {arch:24s} {shape_name:12s} {rec['mesh']:10s} "
                  f"{rec['error']}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())

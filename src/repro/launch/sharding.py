"""Per-architecture PartitionSpec rules for params, optimizer state, inputs
and caches on the (pod, data, model) production mesh.

Two parameter modes:
  * ``train`` — FSDP-style: tensor-parallel dim over "model", plus the
    d_model (or another large) dim over "data" so gradients + AdamW moments
    fit HBM; weights are all-gathered per layer by GSPMD/shard_map (the
    standard ZeRO-3 schedule).
  * ``serve`` — weights sharded over "model" only and replicated over the
    batch axes (fast per-step access, no per-layer gathers).

Expert weights always carry the expert axis on "model" — the paper's expert
parallelism (docs/DESIGN.md §5) — matching core/expert_parallel's shard_map
in_specs.  Divisibility fallbacks (replicate when a dim does not divide the
axis) are the granite-40-experts / qwen2-vl-28-heads cases from docs/DESIGN.md §4.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import quant as quant_lib
from repro.launch import mesh as mesh_lib


def _dim_ok(size: int, mesh, axis: str) -> bool:
    return axis in mesh.axis_names and size % mesh.shape[axis] == 0


def _spec(ndim: int, **at) -> P:
    """Build a PartitionSpec of rank ``ndim`` with axes at given positions,
    e.g. _spec(3, **{'2': 'model'}) -> P(None, None, 'model')."""
    out = [None] * ndim
    for pos, ax in at.items():
        out[int(pos)] = ax
    return P(*out)


def params_pspec(cfg, mesh, params, mode: str = "train"):
    """PartitionSpec pytree matching ``params``. ``mode``: train | serve."""
    tp = "model"
    fsdp = "data" if (mode == "train" and "data" in mesh.axis_names) else None

    def _axes_divide(spec: P, shape) -> P:
        """Drop spec axes whose mesh extent no longer divides the leaf dim
        — QuantTensor payload/scale leaves shrink the reduction axis
        (int4 packing, per-block scales), so a spec derived from the
        logical weight may stop dividing; replicating that dim is always
        safe (GSPMD is value-semantic over any layout)."""
        out = []
        for i, ax in enumerate(spec):
            if ax is None:
                out.append(None)
                continue
            size = mesh_lib.axes_size(
                mesh, ax if isinstance(ax, tuple) else (ax,))
            out.append(ax if size and shape[i] % size == 0 else None)
        return P(*out)

    def rule(path, leaf) -> P:
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        # QuantTensor membership is keyed on the PARENT being a known
        # weight name, not on the field names alone: plain param dicts
        # reuse "scale" (layernorm) and must not be re-keyed
        quant_leaf = (name in ("data", "scale") and len(names) >= 2
                      and names[-2] in quant_lib.WEIGHT_NAMES)
        if quant_leaf:
            # QuantTensor leaves: spec by the logical weight's name (the
            # container's key); payload and scales share every leading
            # axis (layer stack, expert axis), so the same spec applies
            names = names[:-1]
            name = names[-1]
        nd = leaf.ndim
        shape = leaf.shape
        in_experts = "experts" in names
        in_attn = "attn" in names or (names[-2:-1] == ["mix"])

        def sub(**kw):
            spec = _spec(nd, **kw)
            return _axes_divide(spec, shape) if quant_leaf else spec

        def fin(spec: P) -> P:
            return _axes_divide(spec, shape) if quant_leaf else spec

        if name == "embed":                       # (Vpad, D)
            return P(tp, fsdp)
        if name == "lm_head":                     # (D, Vpad)
            return fin(P(fsdp, tp))
        if in_experts:                            # (L, E, D, F) / (L, E, F, D)
            if name in ("w_gate", "w_up"):
                dd = shape[2]
                return fin(P(None, tp, fsdp if _dim_ok(dd, mesh, "data") and fsdp else None, None))
            if name == "w_down":
                dd = shape[3]
                return fin(P(None, tp, None, fsdp if _dim_ok(dd, mesh, "data") and fsdp else None))
            return sub()
        if name == "router":                      # (L, D, E)
            return sub(**({"1": fsdp} if fsdp and _dim_ok(shape[1], mesh, "data") else {}))
        if name in ("wq", "wk", "wv"):            # (L, D, H*hd)
            heads = cfg.num_heads if name == "wq" else cfg.num_kv_heads
            at = {}
            if fsdp and _dim_ok(shape[1], mesh, "data"):
                at["1"] = fsdp
            if heads and heads % mesh.shape[tp] == 0:
                at["2"] = tp
            elif (mode == "serve" and name in ("wk", "wv")
                  and _dim_ok(shape[2], mesh, tp)):
                # serve mode: kv heads may not divide the axis (GQA kv=8 on
                # tp=16) but the flattened Hkv*hd dim does — shard it rather
                # than replicate 2-3 GB of kv weights per device; CP decode
                # gathers only the per-token k/v (KBs), not the weights
                at["2"] = tp
            return sub(**at)
        if name == "wo":                          # (L, H*hd, D)
            at = {}
            if cfg.num_heads and cfg.num_heads % mesh.shape[tp] == 0:
                at["1"] = tp
            if fsdp and _dim_ok(shape[2], mesh, "data"):
                at["2"] = fsdp
            return sub(**at)
        if name in ("w_gate", "w_up"):            # (L, D, F) dense mlp
            at = {}
            if fsdp and _dim_ok(shape[1], mesh, "data"):
                at["1"] = fsdp
            if _dim_ok(shape[2], mesh, tp):
                at["2"] = tp
            return sub(**at)
        if name == "w_down":                      # (L, F, D)
            at = {}
            if _dim_ok(shape[1], mesh, tp):
                at["1"] = tp
            if fsdp and _dim_ok(shape[2], mesh, "data"):
                at["2"] = fsdp
            return sub(**at)
        # mamba2 projections
        if name == "in_proj":                     # (L, D, Z) ragged out dim
            return sub(**({"1": fsdp} if fsdp and _dim_ok(shape[1], mesh, "data") else {}))
        if name == "out_proj":                    # (L, di, D)
            return sub(**({"2": fsdp} if fsdp and _dim_ok(shape[2], mesh, "data") else {}))
        # rg-lru
        if name in ("in_x", "in_y"):              # (L, D, W)
            at = {}
            if fsdp and _dim_ok(shape[1], mesh, "data"):
                at["1"] = fsdp
            if _dim_ok(shape[2], mesh, tp):
                at["2"] = tp
            return sub(**at)
        if name in ("gate_a", "gate_x"):          # (L, W, W)
            return sub(**({"2": tp} if _dim_ok(shape[2], mesh, tp) else {}))
        if name == "out":                         # (L, W, D)
            at = {}
            if _dim_ok(shape[1], mesh, tp):
                at["1"] = tp
            if fsdp and _dim_ok(shape[2], mesh, "data"):
                at["2"] = fsdp
            return sub(**at)
        # norms, biases, conv kernels, Lambda, A_log, D, dt_bias, scalars
        return sub()

    return jax.tree_util.tree_map_with_path(rule, params)


def opt_pspec(cfg, mesh, opt_state, params_spec):
    """AdamW state: moments shard like params, step replicated."""
    return type(opt_state)(P(), params_spec, params_spec)


def batch_pspec(cfg, mesh, batch: dict) -> dict:
    """Global batch: leading dim over the data axes when divisible."""
    ba = mesh_lib.batch_axes(mesh)
    nb = mesh_lib.axes_size(mesh, ba)

    def per(v):
        b = v.shape[0]
        ax = ba if (nb and b % nb == 0) else ()
        return _spec(v.ndim, **{"0": ax}) if ax else _spec(v.ndim)

    return {k: per(v) for k, v in batch.items()}


def cache_pspec(cfg, mesh, cache) -> dict:
    """KV / state caches: (L, B, S, Hkv, hd) — batch over data axes, plus one
    "model"-axis dim chosen by ``cfg.kv_cache_shard``:

      * ``hd``  — shard the head dim (default): decode attention keeps the
        cache update local and turns the QK contraction into a psum;
      * ``seq`` — decode-time context parallelism over the cache length
        (forces a gather/reshard around the attention in GSPMD);
      * ``kv``  — shard kv heads (only when H_kv divides the axis);
      * ``none``— batch-only.

    SSM / conv states are batch-sharded only."""
    ba = mesh_lib.batch_axes(mesh)
    nb = mesh_lib.axes_size(mesh, ba)
    mode = getattr(cfg, "kv_cache_shard", "hd")

    def rule(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        nd = leaf.ndim
        b = leaf.shape[1] if nd >= 2 else 0
        bax = ba if (nb and b % nb == 0) else ()
        at = {}
        if bax:
            at["1"] = bax
        if name in ("k", "v") and nd == 5:
            dim = {"seq": 2, "kv": 3, "hd": 4}.get(mode)
            if dim is not None and _dim_ok(leaf.shape[dim], mesh, "model"):
                at[str(dim)] = "model"
        if name in ("k_scale", "v_scale") and nd == 5 and mode == "seq" \
                and _dim_ok(leaf.shape[2], mesh, "model"):
            at["2"] = "model"
        return _spec(nd, **at)

    return jax.tree_util.tree_map_with_path(rule, cache)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))

"""Serving launcher: continuous batching through the redesigned ServingEngine
(batched one-jit-call prefill, async decode, device-side routing capture).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
        --reduced --requests 8 --new-tokens 16

Reports the paper's §5.2-style breakdown: prompt-evaluation and
token-generation throughput, plus the measured E[#exec experts/node/layer]
statistic that feeds the perf model (Table 1).  The statistic is *exact*:
it is computed from the routing decisions the device returns as auxiliary
forward-pass outputs, not from a host-side router replay (the decode hot
loop performs zero host-side router evaluations).

The default engine is the unified token-budget scheduler
(``EngineConfig.unified_step``): chunked prefill streamed through the cache
and mixed prefill/decode batches in one jit program, so admissions never
stall decode and TTFT/stall are reported honestly.  ``--reference``
restores the two-program engine (padded whole-prompt prefill + decode);
``--legacy`` additionally restores the seed engine's behaviour
(per-request batch-1 prefill, a blocking host sync every decode step) —
``python -m benchmarks.serving_engine`` automates the comparison.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs.base import get_config
from repro.core import perf_model
from repro.serving.engine import EngineConfig, ServingEngine


def serve_demo(cfg, *, requests: int, new_tokens: int, prompt_len: int,
               max_batch: int = 4, seed: int = 0, legacy: bool = False,
               unified: bool = True, chunk_len: int = 32,
               token_budget: int = 0, temperature: float = 0.0,
               top_k: int = 0, paged: bool = False, page_size: int = 16,
               num_pages: int = 0, paged_kernel: bool = False,
               shared_prefix: int = 0,
               weight_quant: str | None = None, fit_cfg=None,
               priorities=None, deadline_ms: float | None = None,
               overcommit: bool = False):
    if weight_quant is not None:
        cfg = cfg.replace(weight_quant=weight_quant)
    fit_cfg = fit_cfg or cfg
    eng = ServingEngine(cfg, EngineConfig(
        max_batch=max_batch, prefill_len=prompt_len,
        max_cache=prompt_len + new_tokens + 8,
        batched_prefill=not legacy, async_steps=not legacy,
        unified_step=unified and not legacy, chunk_len=chunk_len,
        token_budget=token_budget, paged=paged, page_size=page_size,
        num_pages=num_pages, paged_kernel=paged_kernel,
        overcommit=overcommit))
    rng = np.random.default_rng(seed)
    sysp = rng.integers(0, cfg.vocab_size, shared_prefix)
    for k in range(requests):
        plen = int(rng.integers(prompt_len // 2, prompt_len + 1))
        plen = max(plen, min(shared_prefix + 1, prompt_len))
        tail = rng.integers(0, cfg.vocab_size, max(plen - shared_prefix, 1))
        eng.submit(np.concatenate([sysp, tail])[:prompt_len], new_tokens,
                   temperature=temperature, top_k=top_k,
                   priority=(priorities[k % len(priorities)]
                             if priorities else 0),
                   deadline_ms=deadline_ms)
    done = eng.run_until_done()
    tp = eng.throughput()
    mode = ("legacy (seq prefill, sync)" if legacy
            else "paged unified" if paged
            else "unified token-budget" if eng.unified
            else "batched + async (reference)")
    print(f"completed {len(done)} requests [{mode}]")
    print(f"prompt-eval throughput : {tp['prefill_tok_per_s']:.1f} tok/s")
    print(f"generation throughput  : {tp['decode_tok_per_s']:.1f} tok/s")
    print(f"overall throughput     : {tp['total_tok_per_s']:.1f} tok/s")
    print(f"prefill padding overhead: {tp['prefill_padding_overhead']:.1%}  "
          f"decode stall: {tp['decode_stall_s'] * 1e3:.1f} ms")
    ms = eng.memory_stats()
    print(f"device memory          : weights {ms['weight_bytes'] / 1e6:.2f} "
          f"MB (weight_quant={ms['weight_quant']}), KV pool "
          f"{ms['kv_pool_bytes'] / 1e6:.2f} MB, total "
          f"{ms['total_bytes'] / 1e6:.2f} MB")
    tt = eng.ttft()
    if tt["n"]:
        print(f"TTFT p50/p95           : {tt['p50'] * 1e3:.1f} / "
              f"{tt['p95'] * 1e3:.1f} ms over {tt['n']} requests")
    ps = eng.paged_stats()
    if ps.get("paged"):
        print(f"page pool              : {ps['pages_in_use']}/"
              f"{ps['num_pages']} pages in use, high-water "
              f"{ps['pages_hwm']} ({ps['pool_utilization']:.1%} of pool), "
              f"page_size {ps['page_size']}")
        if ps.get("paged_kernel"):
            # attention-read model at end-of-generation context: what the
            # block-table kernel reads vs what the gather path would have
            # mean over the decode trajectory, not the end-of-decode
            # snapshot — at the last step every row fills its block table
            # and the two paths read the same bytes by construction
            rb = perf_model.paged_attention_read_bytes(
                cfg, lengths=[prompt_len + i for i in range(new_tokens)
                              for _ in range(max_batch)],
                page_size=page_size, max_blocks=eng.max_blocks)
            steps = max(new_tokens, 1)
            print(f"paged-attention kernel : block-table decode in VMEM, "
                  f"{rb['kernel_bytes'] / steps / 1e6:.2f} MB/step "
                  f"attention reads vs "
                  f"{rb['gather_bytes'] / steps / 1e6:.2f} MB gather "
                  f"({rb['ratio']:.1f}x)")
        print(f"prefix cache           : hit rate {ps['prefix_hit_rate']:.1%}"
              f" ({ps['prefix_hits']}/{ps['prefix_lookups']} lookups), "
              f"{ps['prefix_hit_tokens']} prefill tokens skipped, "
              f"{ps['prefix_cached_pages']} pages cached, "
              f"{ps['prefix_evictions']} evictions, "
              f"{ps['cow_copies']} CoW copies")
    rs = eng.resilience_stats()
    n_done = sum(1 for r in done if r.status == "done")
    if any(rs.values()) or n_done != len(done):
        print(f"resilience             : {n_done}/{len(done)} done, "
              f"{rs['expired']} expired, {rs['cancelled']} cancelled, "
              f"{rs['failed']} failed; {rs['preemptions']} preemptions / "
              f"{rs['restores']} restores "
              f"({rs['restore_hit_tokens']} tokens restored from prefix "
              f"cache), admitted high-water {rs['active_hwm']}")
    if cfg.is_moe:
        for n in (2, 3, 4):
            e = eng.expected_experts_per_node(n)
            est = perf_model.estimate(
                perf_model.MoEWorkload.from_config(cfg),
                perf_model.M2_ULTRA_10GBE, n, expected_experts=e)
            print(f"E[#exec experts/node/layer] @ {n} nodes: {e:.2f}  "
                  f"(paper-model bound {est.throughput:.1f} tok/s)")
        # the weight-bytes capacity term (docs/DESIGN.md §8): which quant
        # level lets N Table-2 nodes host the arch — always computed from
        # ``fit_cfg`` (main() passes the FULL-SIZE config, so --reduced
        # demos still print the real capacity answer)
        try:
            fit = perf_model.max_model_at_budget(fit_cfg, n_nodes=2)
            lv = fit["level"] or "does not fit (even int4)"
            print(f"memory fit @ 2 M2-Ultra nodes ({fit_cfg.name}): {lv}  "
                  + " ".join(f"{k}={v / 1e9:.1f}GB"
                             for k, v in fit["per_node_bytes"].items()))
        except ValueError:
            pass                       # non-attention family: no model
    return eng, done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--legacy", action="store_true",
                    help="seed-engine behaviour: per-request prefill + "
                         "per-step host sync (for A/B comparison)")
    ap.add_argument("--reference", action="store_true",
                    help="two-program reference engine (batched padded "
                         "prefill + decode; unified_step=False)")
    ap.add_argument("--chunk-len", type=int, default=32,
                    help="unified mode: prefill chunk / block width")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="unified mode: per-iteration prefill-token cap "
                         "(0 = unlimited; decode rows are exempt)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="per-request top-k cut (0 = full vocab)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: page pool + block tables + "
                         "prefix-cache reuse (docs/DESIGN.md §7; implies "
                         "the unified scheduler)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged mode: tokens per page")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="paged mode: pool size in pages (0 = auto: the "
                         "contiguous layout's token capacity)")
    ap.add_argument("--paged-kernel", action="store_true",
                    help="paged mode: attend through the Pallas "
                         "block-table kernel (kernels/paged_attn.py) "
                         "instead of gathering a virtual cache — "
                         "attention reads scale with row lengths, not "
                         "pool size (docs/DESIGN.md §11)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of system prompt shared by every request "
                         "(exercises the prefix cache in --paged mode)")
    ap.add_argument("--priority", type=int, nargs="+", default=None,
                    help="admission priorities, cycled across requests "
                         "(e.g. --priority 0 5: every other request is "
                         "high-priority; higher admits first and, with "
                         "--overcommit, may preempt lower)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request wall-clock deadline from submit; "
                         "unfinished requests expire and release pages")
    ap.add_argument("--overcommit", action="store_true",
                    help="paged mode: admit on current context instead of "
                         "reserving the full lifetime; under pool pressure "
                         "the scheduler preempts low-priority rows into "
                         "the prefix cache and restores them later "
                         "(docs/DESIGN.md §10)")
    ap.add_argument("--weight-quant", choices=["none", "int8", "int4"],
                    default=None,
                    help="blockwise quantized weight store "
                         "(docs/DESIGN.md §8): weights load as int8 / "
                         "packed-int4 QuantTensor leaves with per-block "
                         "fp32 scales; router and embedding stay fp")
    args = ap.parse_args()
    if args.overcommit and not args.paged:
        ap.error("--overcommit requires --paged (it is a page-pool "
                 "admission policy)")
    if args.paged_kernel and not args.paged:
        ap.error("--paged-kernel requires --paged (the kernel attends "
                 "through the page pool's block tables)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    serve_demo(cfg, requests=args.requests, new_tokens=args.new_tokens,
               prompt_len=args.prompt_len, max_batch=args.max_batch,
               legacy=args.legacy, unified=not args.reference,
               chunk_len=args.chunk_len, token_budget=args.token_budget,
               temperature=args.temperature, top_k=args.top_k,
               paged=args.paged, page_size=args.page_size,
               num_pages=args.num_pages, paged_kernel=args.paged_kernel,
               shared_prefix=args.shared_prefix,
               weight_quant=args.weight_quant,
               fit_cfg=get_config(args.arch), priorities=args.priority,
               deadline_ms=args.deadline_ms, overcommit=args.overcommit)


if __name__ == "__main__":
    main()

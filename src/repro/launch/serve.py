"""Serving launcher: batched prefill+decode through the ServingEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
        --reduced --requests 8 --new-tokens 16

Reports the paper's §5.2-style breakdown: prompt-evaluation and
token-generation throughput, plus the measured E[#exec experts/node/layer]
statistic that feeds the perf model (Table 1).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs.base import get_config
from repro.core import perf_model
from repro.serving.engine import EngineConfig, ServingEngine


def serve_demo(cfg, *, requests: int, new_tokens: int, prompt_len: int,
               max_batch: int = 4, seed: int = 0):
    rng = np.random.default_rng(seed)
    eng = ServingEngine(cfg, EngineConfig(
        max_batch=max_batch, prefill_len=prompt_len,
        max_cache=prompt_len + new_tokens + 8))
    for _ in range(requests):
        plen = int(rng.integers(prompt_len // 2, prompt_len + 1))
        eng.submit(rng.integers(0, cfg.vocab_size, plen), new_tokens)
    done = eng.run_until_done()
    tp = eng.throughput()
    print(f"completed {len(done)} requests")
    print(f"prompt-eval throughput : {tp['prefill_tok_per_s']:.1f} tok/s")
    print(f"generation throughput  : {tp['decode_tok_per_s']:.1f} tok/s")
    if cfg.is_moe:
        for n in (2, 3, 4):
            e = eng.expected_experts_per_node(n)
            est = perf_model.estimate(
                perf_model.MoEWorkload.from_config(cfg),
                perf_model.M2_ULTRA_10GBE, n, expected_experts=e)
            print(f"E[#exec experts/node/layer] @ {n} nodes: {e:.2f}  "
                  f"(paper-model bound {est.throughput:.1f} tok/s)")
    return eng, done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    serve_demo(cfg, requests=args.requests, new_tokens=args.new_tokens,
               prompt_len=args.prompt_len, max_batch=args.max_batch)


if __name__ == "__main__":
    main()

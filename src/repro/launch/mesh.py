"""Mesh construction for the production TPU v5e deployment and CPU tests.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dryrun sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init
and everything else must see the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 4):
    """Small host-device mesh for CPU integration tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count >= n_data*n_model)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n

"""Training launcher: real steps on whatever devices exist.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \
        --reduced --steps 50 --batch 8 --seq 128

On the CPU container this runs reduced configs end-to-end (the examples/
drivers call into here); on a real TPU slice the same entry point takes the
full configs with the production mesh (--mesh data,model=...).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.ckpt import io as ckpt_io
from repro.configs.base import get_config
from repro.data.pipeline import Pipeline, PipelineConfig, shard_batch
from repro.launch import sharding
from repro.models.model import build_model


def make_train_step(model, ocfg, mesh):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch, mesh)
        params, opt_state, om = optim.update(ocfg, grads, opt_state, params)
        return params, opt_state, {**metrics, **om, "loss": loss}
    return train_step


def train(cfg, *, steps: int, global_batch: int, seq_len: int,
          mesh=None, lr: float = 3e-4, log_every: int = 10,
          ckpt_path: str | None = None, seed: int = 0):
    model = build_model(cfg)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    ocfg = optim.OptimizerConfig(lr=lr, total_steps=steps,
                                 warmup_steps=max(steps // 20, 5))
    opt_state = optim.init(params)

    if mesh is not None:
        p_spec = sharding.params_pspec(cfg, mesh, params, mode="train")
        params = jax.device_put(params, sharding.named(mesh, p_spec))
        o_spec = sharding.opt_pspec(cfg, mesh, opt_state, p_spec)
        opt_state = jax.device_put(opt_state, sharding.named(mesh, o_spec))

    step_fn = jax.jit(make_train_step(model, ocfg, mesh),
                      donate_argnums=(0, 1))

    pipe = Pipeline(PipelineConfig(seq_len=seq_len, global_batch=global_batch,
                                   vocab_size=cfg.vocab_size, seed=seed))
    history = []
    t0 = time.time()
    for step in range(steps):
        np_batch = pipe.next_batch()
        if cfg.family == "audio":
            # frontend stub: frame embeddings instead of token ids
            b, s = np_batch["tokens"].shape
            emb = np.take(np.asarray(jax.device_get(params["embed"]))
                          if not isinstance(params["embed"], jnp.ndarray)
                          else np.asarray(params["embed"], np.float32),
                          np_batch["tokens"] % cfg.vocab_size, axis=0)
            batch = {"frame_embeds": jnp.asarray(emb, cfg.dtype_jnp),
                     "labels": jnp.asarray(np_batch["labels"])}
        else:
            batch = {k: jnp.asarray(v) for k, v in np_batch.items()}
        if mesh is not None:
            batch = shard_batch(batch, mesh)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"], m["wall_s"] = step, round(time.time() - t0, 2)
            history.append(m)
            print(f"step {step:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                  f"aux {m.get('aux', 0.0):.4f} lr {m['lr']:.2e} "
                  f"gnorm {m['grad_norm']:.3f} [{m['wall_s']}s]", flush=True)
    if ckpt_path:
        ckpt_io.save(ckpt_path, params, step=steps)
        print(f"saved checkpoint to {ckpt_path}")
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--moe-strategy", default=None,
                    choices=[None, "dense", "dispatch"])
    ap.add_argument("--expert-parallel", default=None,
                    choices=[None, "centralized", "decentralized", "a2a"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    over = {}
    if args.moe_strategy:
        over["moe_strategy"] = args.moe_strategy
    if args.expert_parallel:
        over["expert_parallel"] = args.expert_parallel
    if over:
        cfg = cfg.replace(**over)
    train(cfg, steps=args.steps, global_batch=args.batch, seq_len=args.seq,
          lr=args.lr, ckpt_path=args.ckpt)


if __name__ == "__main__":
    main()

"""HLO-text analysis for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` visits each computation ONCE — a
``lax.scan`` over L layers reports 1/L of the real FLOPs (verified
empirically on the CPU backend).  This module re-derives roofline inputs
from ``compiled.as_text()`` *with while-loop trip-count multipliers*:

  * ``flops``            — 2·prod(result)·prod(contracting dims) per dot,
    trip-multiplied through nested while loops;
  * ``bytes``            — Σ (operand + result bytes) over top-level
    instructions (fusions counted at the call site = post-fusion HBM
    traffic; fusion bodies and to_apply regions are not traversed);
  * ``collective_bytes`` — per collective kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), operand bytes,
    trip-multiplied.  All numbers are PER-DEVICE (the module is SPMD).

Scheduled HLO does not inline operand shapes, so the parser keeps a
per-computation symbol table (instruction -> result shapes) and resolves
operands through it.  Trip counts come from the integer constants in each
while condition (a scan condition is ``i < L``); nested loops multiply.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops whose operand/result bytes are control flow, not HBM traffic
_NO_BYTES = ("parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "while", "conditional", "call", "after-all", "partition-id",
             "replica-id", "custom-call", "copy-start", "copy-done",
             "add-dependency", "domain", "opt-barrier")
# ops that represent real HBM traffic on a TPU build (dots, fused kernels,
# data movement).  Standalone elementwise ops / converts / broadcasts are
# excluded from the HBM estimate: the TPU backend fuses them into neighbours
# while the CPU backend leaves many of them unfused, which would bill each
# at HBM cost and overstate the memory roofline term by an order of
# magnitude (verified on qwen2-72b train: raw 431 s vs compute 15 s).
_HBM_OPS = ("dot", "convolution", "fusion", "dynamic-slice",
            "dynamic-update-slice", "gather", "scatter", "copy",
            "concatenate", "pad", "reduce", "reduce-window", "sort",
            "transpose", "reshape", "slice", "select-and-scatter",
            "rng", "rng-bit-generator", "iota", "cholesky",
            "triangular-solve")

_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s*([a-z][\w\-]*)\((.*)$")
_WHILE_ATTR = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_ATTR = re.compile(r"to_apply=%?([\w\.\-]+)")
_FUSION_ATTR = re.compile(r"calls=%?([\w\.\-]+)")
_BRANCH_ATTR = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_ATTR = re.compile(r"true_computation=%?([\w\.\-]+),\s*"
                      r"false_computation=%?([\w\.\-]+)")
_FUSION_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def shape_bytes(dtype: str, dims: str) -> int:
    return _shape_elems(dims) * _DTYPE_BYTES[dtype]


def _shapes_bytes(shapes) -> float:
    return float(sum(shape_bytes(dt, d) for dt, d in shapes))


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool = False
    flops: float = 0.0
    bytes: float = 0.0
    hbm_bytes: float = 0.0       # _HBM_OPS only — the TPU traffic estimate
    convert_bytes: float = 0.0   # dtype-convert traffic (CPU bf16 upcasts)
    coll: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    whiles: list = dataclasses.field(default_factory=list)  # (body, cond)
    calls: list = dataclasses.field(default_factory=list)
    max_const: int = 1
    has_ds: bool = False         # body contains dynamic-slice
    has_dus: bool = False        # body contains dynamic-update-slice


def _split_operands(rest: str) -> list[str]:
    """Names referenced inside the operand parens (up to the matching ')')."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return _OPERAND_RE.findall(rest[:i])
    return _OPERAND_RE.findall(rest)


def parse(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    symtab: dict[str, list] = {}
    entry_name = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if not line or line.startswith(("//", "#")):
            continue
        mh = _COMP_HEAD.match(line)
        if mh and line.endswith("{"):
            cur = Computation(mh.group(2), is_entry=bool(mh.group(1)))
            comps[cur.name] = cur
            symtab = {}
            if cur.is_entry:
                entry_name = cur.name
            continue
        if line.startswith("}") or cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, result_part, opcode, rest = mi.groups()
        result_shapes = _SHAPE_RE.findall(result_part)
        symtab[name] = result_shapes
        for mc in _CONST_RE.finditer(line):
            cur.max_const = max(cur.max_const, int(mc.group(1)))
        if opcode == "while":
            mw = _WHILE_ATTR.search(rest)
            if mw:
                cur.whiles.append((mw.group(2), mw.group(1)))
            continue
        if opcode == "call":
            mc2 = _CALL_ATTR.search(rest)
            if mc2:
                cur.calls.append(mc2.group(1))
            continue
        if opcode == "conditional":
            mb = _BRANCH_ATTR.search(rest)
            if mb:
                cur.calls.extend(t.strip().lstrip("%") for t in
                                 mb.group(1).split(",") if t.strip())
            else:
                mtf = _TF_ATTR.search(rest)
                if mtf:
                    cur.calls.extend(mtf.groups())
            continue

        if "dynamic-slice(" in line:
            cur.has_ds = True
        if "dynamic-update-slice(" in line:
            cur.has_dus = True

        operand_names = _split_operands(rest)
        operand_shapes = [s for o in operand_names for s in symtab.get(o, [])]

        base = opcode.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES or opcode in _COLLECTIVES:
            nb = _shapes_bytes(operand_shapes)
            if nb == 0:  # -done ops reference the -start tuple
                nb = _shapes_bytes(result_shapes)
            if not opcode.endswith("-done"):
                cur.coll[base] += nb
                cur.bytes += nb + _shapes_bytes(result_shapes)
            continue
        if opcode in _NO_BYTES:
            continue
        all_shapes = result_shapes + operand_shapes
        nb = _shapes_bytes(all_shapes)
        effective = opcode
        callee_flags = (False, False)
        if opcode == "fusion":
            # CPU wraps single ops as %wrapped_<op> fusions — classify by
            # the wrapped op so e.g. wrapped_convert is not billed as HBM
            mf = _FUSION_CALLS_RE.search(rest)
            if mf:
                mw = re.match(r"wrapped_([a-z\-_]+?)(?:_computation)?$",
                              mf.group(1))
                if mw:
                    effective = mw.group(1).replace("_", "-")
                body = comps.get(mf.group(1))
                if body is not None:
                    callee_flags = (body.has_ds, body.has_dus)
        # scan-style windowed accesses: a dynamic-slice reads only the slice
        # (not the whole stacked operand) and a dynamic-update-slice writes
        # in place — bill the window, not the full (L, ...) array, otherwise
        # every lax.scan layer step is charged the entire weight/cache stack
        is_ds = effective == "dynamic-slice" or callee_flags[0]
        is_dus = effective == "dynamic-update-slice" or callee_flags[1]
        if (is_ds or is_dus) and all_shapes:
            biggest = max(shape_bytes(dt, d) for dt, d in all_shapes)
            drop = 2 * biggest if is_dus else biggest
            nb = max(nb - drop, min(shape_bytes(dt, d)
                                    for dt, d in all_shapes))
        cur.bytes += nb
        if effective in _HBM_OPS:
            cur.hbm_bytes += nb
        if effective == "convert":
            cur.convert_bytes += nb
        if opcode == "dot":
            mct = _CONTRACT_RE.search(rest)
            lhs = symtab.get(operand_names[0], []) if operand_names else []
            if mct and lhs:
                lhs_dims = ([int(x) for x in lhs[0][1].split(",")]
                            if lhs[0][1] else [])
                cprod = 1
                for cd in (int(x) for x in mct.group(1).split(",") if x):
                    if cd < len(lhs_dims):
                        cprod *= lhs_dims[cd]
                rprod = 1
                for dt, dims in result_shapes:
                    rprod *= _shape_elems(dims)
                cur.flops += 2.0 * rprod * cprod

    comps["__entry__"] = comps.get(entry_name, Computation("none"))
    return comps


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    hbm_bytes: float = 0.0
    convert_bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=lambda: defaultdict(float))

    @property
    def collective_bytes(self) -> float:
        return float(sum(self.coll.values()))

    @property
    def bytes_tpu_est(self) -> float:
        """HBM traffic with dtype-convert ops removed — on TPU the bf16
        operands feed the MXU directly; the CPU backend's wholesale
        bf16->f32 upcasts (and their hoisted buffers) do not exist there."""
        return self.bytes - self.convert_bytes


def breakdown(hlo_text: str, top: int = 20) -> list[tuple[str, float, float]]:
    """Top HBM-byte contributors as (opcode@result_shape, bytes, flops),
    trip-multiplied — the dry-run 'profile' used by the §Perf loop."""
    items: dict[str, list] = {}
    comps: dict[str, Computation] = {}
    cur = None
    symtab: dict[str, list] = {}
    trip_of: dict[str, float] = {}
    # pass 1: parse computations again, but track per-instruction keys
    per_comp_items: dict[str, dict] = {}
    flags: dict[str, tuple] = {}
    cur_flags = [False, False]
    for raw in hlo_text.splitlines():
        line = raw.strip()
        mh = _COMP_HEAD.match(line)
        if mh and line.endswith("{"):
            cur = mh.group(2)
            per_comp_items[cur] = {}
            symtab = {}
            cur_flags = [False, False]
            flags[cur] = cur_flags
            continue
        if line.startswith("}") or cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, result_part, opcode, rest = mi.groups()
        result_shapes = _SHAPE_RE.findall(result_part)
        symtab[name] = result_shapes
        if "dynamic-slice(" in line:
            cur_flags[0] = True
        if "dynamic-update-slice(" in line:
            cur_flags[1] = True
        if opcode in _NO_BYTES or opcode in ("while", "call", "conditional"):
            continue
        operand_names = _split_operands(rest)
        operand_shapes = [s for o in operand_names for s in symtab.get(o, [])]
        all_shapes = result_shapes + operand_shapes
        nb = _shapes_bytes(all_shapes)
        is_ds = opcode == "dynamic-slice"
        is_dus = opcode == "dynamic-update-slice"
        if opcode == "fusion":
            mf = _FUSION_CALLS_RE.search(rest)
            if mf and mf.group(1) in flags:
                is_ds = is_ds or flags[mf.group(1)][0]
                is_dus = is_dus or flags[mf.group(1)][1]
        if (is_ds or is_dus) and all_shapes:
            biggest = max(shape_bytes(dt, d) for dt, d in all_shapes)
            drop = 2 * biggest if is_dus else biggest
            nb = max(nb - drop, min(shape_bytes(dt, d)
                                    for dt, d in all_shapes))
        key = opcode + "@" + (
            result_shapes[0][0] + "[" + result_shapes[0][1] + "]"
            if result_shapes else "?")
        d = per_comp_items[cur].setdefault(key, [0.0, 0.0])
        d[0] += nb

    # pass 2: reuse parse() for the call graph / trip counts
    comps = parse(hlo_text)
    entry = comps["__entry__"].name
    mult: dict[str, float] = {entry: 1.0}

    def spread(name, m, stack=()):
        if name not in comps or name in stack:
            return
        c = comps[name]
        for callee in c.calls:
            mult[callee] = mult.get(callee, 0.0) + m
            spread(callee, m, stack + (name,))
        for body, cond in c.whiles:
            trip = comps[cond].max_const if cond in comps else 1
            mult[body] = mult.get(body, 0.0) + m * trip
            spread(body, m * trip, stack + (name,))

    spread(entry, 1.0)
    agg: dict[str, float] = {}
    for comp, it in per_comp_items.items():
        m = mult.get(comp, 0.0)
        if m <= 0:
            continue
        for key, (nb, _) in it.items():
            agg[key] = agg.get(key, 0.0) + nb * m
    ranked = sorted(agg.items(), key=lambda kv: -kv[1])[:top]
    return [(k, v, 0.0) for k, v in ranked]


def sized_copies(hlo_text: str, min_bytes: int) -> list[tuple[str, int]]:
    """Every ``copy`` / ``copy-start`` instruction whose destination buffer
    is >= ``min_bytes``, as (stripped instruction line, destination bytes).

    The zero-copy serving regression (tests/test_zero_copy.py and analysis
    rule R1) uses this on the compiled decode step: with the cache donated
    and updated via dynamic_update_slice on a scan carry, the program must
    contain no copy the size of a full cache leaf — XLA's way of
    materializing either a non-aliased input (the paper's C1
    memory-management overhead) or a gqa_repeat of the cache.

    Async copies count too: a ``copy-start`` moves the same bytes as a plain
    ``copy``, it just overlaps the transfer — its result is a
    ``(dest, src, context)`` tuple, so the destination is the first result
    shape.  The matching ``copy-done`` only unpacks that tuple and is
    skipped (counting both would double-bill the pair)."""
    out = []
    for raw in hlo_text.splitlines():
        line = raw.strip()
        m = _INSTR_RE.match(line)
        if not m:
            continue
        _, result_part, opcode, _ = m.groups()
        if opcode not in ("copy", "copy-start"):
            continue
        shapes = _SHAPE_RE.findall(result_part)
        if not shapes:
            continue
        nb = shape_bytes(*shapes[0])
        if nb >= min_bytes:
            out.append((line, nb))
    return out


def sized_gathers(hlo_text: str, min_bytes: int) -> list[tuple[str, int]]:
    """Every ``gather`` instruction whose RESULT buffer is >= ``min_bytes``,
    as (stripped instruction line, result bytes).

    The paged-attention lint (analysis rule R1 on the ``paged_kernel``
    variant) uses this on the compiled unified step: the gather-path
    program materializes each row's pages as a (B, NB*page_size, Hkv, hd)
    virtual cache — an HLO ``gather`` of exactly that size per pool leaf —
    while the Pallas block-table kernel must contain none (the kernel's
    page walk is BlockSpec indexing inside a custom-call, invisible to
    XLA's gather op).  Matching is by result size, not operand size: MoE
    expert-weight gathers legitimately read pool-scale operands."""
    out = []
    for raw in hlo_text.splitlines():
        line = raw.strip()
        m = _INSTR_RE.match(line)
        if not m:
            continue
        _, result_part, opcode, _ = m.groups()
        if opcode != "gather":
            continue
        shapes = _SHAPE_RE.findall(result_part)
        if not shapes:
            continue
        nb = shape_bytes(*shapes[0])
        if nb >= min_bytes:
            out.append((line, nb))
    return out


@dataclasses.dataclass(frozen=True)
class AliasPair:
    """One entry of the module's ``input_output_alias`` map.

    ``param_number`` is the flat entry-parameter index; ``param_index`` /
    ``output_index`` are tuple paths inside that parameter / the result
    tuple (empty for non-nested shapes)."""
    output_index: tuple
    param_number: int
    param_index: tuple
    kind: str  # "may-alias" | "must-alias"


_ALIAS_ENTRY_RE = re.compile(
    r"\{([0-9,\s]*)\}\s*:\s*\(\s*(\d+)\s*,\s*\{([0-9,\s]*)\}\s*,"
    r"\s*(may-alias|must-alias)\s*\)")


def _int_tuple(csv: str) -> tuple:
    return tuple(int(x) for x in csv.replace(" ", "").split(",") if x)


def input_output_alias_pairs(hlo_text: str) -> list[AliasPair]:
    """The donated-parameter alias map from the module header, as actual
    (output, param) pairs — so a lint can name WHICH donated leaf failed to
    alias, not just count survivors.

    The map is extracted by brace matching from ``input_output_alias={``
    onward (no assumption about which attribute follows it in the header)."""
    key = "input_output_alias={"
    start = hlo_text.find(key)
    if start < 0:
        return []
    i = start + len(key) - 1  # position of the opening brace
    depth = 0
    body = None
    for j in range(i, len(hlo_text)):
        ch = hlo_text[j]
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth == 0:
                body = hlo_text[i + 1:j]
                break
    if body is None:
        return []
    return [
        AliasPair(_int_tuple(m.group(1)), int(m.group(2)),
                  _int_tuple(m.group(3)), m.group(4))
        for m in _ALIAS_ENTRY_RE.finditer(body)
    ]


def input_output_aliases(hlo_text: str) -> int:
    """Number of donated-parameter aliases in the module header (0 when the
    jit was compiled without ``donate_argnums`` or donation was unusable)."""
    return len(input_output_alias_pairs(hlo_text))


def collective_ops(hlo_text: str) -> list[tuple[str, int, str]]:
    """Every collective instruction as (kind, dest bytes, stripped line).

    ``kind`` is the base opcode (``all-gather-start`` -> ``all-gather``);
    the matching ``-done`` halves are skipped so async pairs are billed
    once.  ``dest bytes`` is the largest result buffer — for an all-gather
    that is the gathered (unsharded) array, which is what the sharding lint
    (R6) compares against full expert-weight leaf sizes."""
    out = []
    for raw in hlo_text.splitlines():
        line = raw.strip()
        m = _INSTR_RE.match(line)
        if not m:
            continue
        _, result_part, opcode, _ = m.groups()
        base = opcode.replace("-start", "").replace("-done", "")
        if base not in _COLLECTIVES or opcode.endswith("-done"):
            continue
        shapes = _SHAPE_RE.findall(result_part)
        nb = max((shape_bytes(dt, d) for dt, d in shapes), default=0)
        out.append((base, nb, line))
    return out


def analyze(hlo_text: str) -> Totals:
    comps = parse(hlo_text)
    entry = comps["__entry__"]
    memo: dict[str, Totals] = {}

    def walk(name: str, stack=()) -> Totals:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return Totals()
        c = comps[name]
        t = Totals(c.flops, c.bytes, c.hbm_bytes, c.convert_bytes,
                   defaultdict(float, c.coll))
        for callee in c.calls:
            sub = walk(callee, stack + (name,))
            t.flops += sub.flops
            t.bytes += sub.bytes
            t.hbm_bytes += sub.hbm_bytes
            t.convert_bytes += sub.convert_bytes
            for k, v in sub.coll.items():
                t.coll[k] += v
        for body, cond in c.whiles:
            trip = comps[cond].max_const if cond in comps else 1
            sub = walk(body, stack + (name,))
            t.flops += trip * sub.flops
            t.bytes += trip * sub.bytes
            t.hbm_bytes += trip * sub.hbm_bytes
            t.convert_bytes += trip * sub.convert_bytes
            for k, v in sub.coll.items():
                t.coll[k] += trip * v
        memo[name] = t
        return t

    return walk(entry.name)

from repro.data.pipeline import Pipeline, PipelineConfig, SyntheticSource, MemmapSource, shard_batch

"""Token data pipeline: synthetic + memmap-file sources, document packing,
global-batch sharding.

Sources
  * ``SyntheticSource``  — deterministic pseudo-corpus (zipf-ish unigram over
    the vocab seeded per shard); used by examples/tests so everything runs
    offline.
  * ``MemmapSource``     — flat .bin of uint16/uint32 token ids (the usual
    "tokenized corpus on disk" format); zero-copy windowed reads.

``Pipeline`` packs documents into fixed-length rows (next-token labels, EOS
separated), yields numpy batches of the *global* batch size, and
``shard_batch`` places them on the mesh with a (pod, data)-sharded batch
axis — the on-host half of the distributed input pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

try:  # jax only needed for shard_batch
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
except Exception:  # pragma: no cover
    jax = None


# ---------------------------------------------------------------------------
# sources
# ---------------------------------------------------------------------------

class SyntheticSource:
    """Deterministic document stream: doc lengths ~ U[32, 4*seq), zipf-ish
    unigram token distribution; reproducible per (seed, shard)."""

    def __init__(self, vocab_size: int, seed: int = 0, mean_len: int = 512):
        self.vocab = max(vocab_size, 4)
        self.rng = np.random.default_rng(seed)
        self.mean_len = mean_len
        # zipf-ish fixed unigram distribution
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.p = p / p.sum()

    def documents(self) -> Iterator[np.ndarray]:
        while True:
            n = int(self.rng.integers(32, 4 * self.mean_len))
            yield self.rng.choice(self.vocab, size=n, p=self.p).astype(np.int32)


class MemmapSource:
    """Flat binary token file. ``dtype`` uint16 for vocab<65536 else uint32."""

    def __init__(self, path: str, dtype=np.uint16, doc_sep: int | None = None):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.doc_sep = doc_sep

    def documents(self) -> Iterator[np.ndarray]:
        if self.doc_sep is None:
            # treat the whole file as one stream of fixed 2048-token docs
            step = 2048
            while True:
                for i in range(0, len(self.data) - step, step):
                    yield np.asarray(self.data[i:i + step], dtype=np.int32)
        else:
            bounds = np.flatnonzero(self.data == self.doc_sep)
            while True:
                start = 0
                for b in bounds:
                    if b > start:
                        yield np.asarray(self.data[start:b], dtype=np.int32)
                    start = b + 1


# ---------------------------------------------------------------------------
# packing pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PipelineConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    eos_id: int = 0
    seed: int = 0


class Pipeline:
    """Packs documents into (global_batch, seq_len) token/label rows."""

    def __init__(self, cfg: PipelineConfig, source=None):
        self.cfg = cfg
        self.source = source or SyntheticSource(cfg.vocab_size, cfg.seed)
        self._docs = self.source.documents()
        self._buf = np.zeros((0,), np.int32)

    def _fill(self, n: int) -> np.ndarray:
        parts = [self._buf]
        have = len(self._buf)
        while have < n:
            d = next(self._docs)
            parts.append(np.append(d, self.cfg.eos_id).astype(np.int32))
            have += len(d) + 1
        flat = np.concatenate(parts)
        self._buf = flat[n:]
        return flat[:n]

    def next_batch(self) -> dict:
        """Returns {"tokens": (B, S) int32, "labels": (B, S) int32} where
        labels are next-token targets (last position predicts EOS)."""
        b, s = self.cfg.global_batch, self.cfg.seq_len
        flat = self._fill(b * (s + 1))
        rows = flat.reshape(b, s + 1)
        return {"tokens": np.ascontiguousarray(rows[:, :-1]),
                "labels": np.ascontiguousarray(rows[:, 1:])}

    def __iter__(self):
        while True:
            yield self.next_batch()


def shard_batch(batch: dict, mesh, batch_axes=("pod", "data")) -> dict:
    """Place a host numpy batch onto the mesh, batch dim sharded over the
    data axes and everything else replicated."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def put(x):
        spec = P(axes, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return {k: put(v) for k, v in batch.items()}

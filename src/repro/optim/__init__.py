from repro.optim import adamw
from repro.optim.adamw import (OptimizerConfig, OptState, init, update, lr_at,
                               clip_by_global_norm, global_norm)

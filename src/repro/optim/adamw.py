"""AdamW with cosine schedule, linear warmup and global-norm clipping.

Self-contained (no optax in this container).  State is a pytree matching
params, sharded identically via jax.tree.map — works unchanged under pjit
because every op is elementwise over leaves.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: Array     # () int32
    mu: Any         # first moment, same tree as params
    nu: Any         # second moment


def lr_at(cfg: OptimizerConfig, step: Array) -> Array:
    """Linear warmup then cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decayed


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def update(cfg: OptimizerConfig, grads, state: OptState, params):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {
        "lr": lr, "grad_norm": gnorm}

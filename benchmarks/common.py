"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import time

import jax

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def save_result(name: str, payload: dict) -> str:
    os.makedirs(os.path.join(RESULTS_DIR, "bench"), exist_ok=True)
    path = os.path.join(RESULTS_DIR, "bench", f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def time_fn(fn, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall-time of a jit'd function (seconds)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def markdown_table(headers: list[str], rows: list[list]) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)

"""§Roofline: aggregate the dry-run records into the per-(arch x shape)
roofline table (compute / memory / collective terms, dominant bottleneck,
MODEL_FLOPS ratio)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR, markdown_table, save_result


def load_records(mesh: str = "16x16", tag: str = "") -> list[dict]:
    recs = []
    pat = os.path.join(RESULTS_DIR, "dryrun", f"*_{mesh}{tag}.json")
    for path in sorted(glob.glob(pat)):
        with open(path) as f:
            r = json.load(f)
        if r.get("ok"):
            recs.append(r)
    return recs


def run(mesh: str = "16x16") -> dict:
    recs = load_records(mesh)
    rows = []
    for r in recs:
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "dominant": rl["dominant"],
            "useful_flop_ratio": rl["useful_flop_ratio"],
            "peak_gib": r["memory"]["peak_per_device_tpu_est"] / 2**30,
        })
    out = {"mesh": mesh, "rows": rows}
    save_result(f"roofline_{mesh}", out)
    return out


def render(out: dict) -> str:
    hdr = ["arch", "shape", "compute (s)", "memory (s)", "collective (s)",
           "dominant", "useful FLOP ratio", "peak GiB/dev"]
    body = [[r["arch"], r["shape"], f"{r['compute_s']:.2e}",
             f"{r['memory_s']:.2e}", f"{r['collective_s']:.2e}",
             r["dominant"], f"{r['useful_flop_ratio']:.2f}",
             f"{r['peak_gib']:.2f}"]
            for r in sorted(out["rows"], key=lambda x: (x["arch"], x["shape"]))]
    return markdown_table(hdr, body)


if __name__ == "__main__":
    print(render(run()))

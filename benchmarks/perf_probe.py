"""§Perf diagnostic: lower one (arch, shape), print roofline terms and the
top HBM/collective contributors (trip-multiplied).

    PYTHONPATH=src:. python -m benchmarks.perf_probe --arch qwen2_72b \
        --shape train_4k [--override '{"moe_strategy": "dense"}']
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json

from repro.launch import hlo
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS, lower_pair
from repro.launch.mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--override", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=18)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    over = json.loads(args.override) if args.override else None
    compiled, cfg = lower_pair(args.arch, args.shape, mesh, over)
    txt = compiled.as_text()
    t = hlo.analyze(txt)
    print(f"compute={t.flops / PEAK_FLOPS:.3e}s "
          f"memory={t.hbm_bytes / HBM_BW:.3e}s "
          f"collective={t.collective_bytes / ICI_BW:.3e}s")
    print(f"coll by kind: "
          f"{ {k: f'{v:.3e}' for k, v in t.coll.items()} }")
    print("\ntop HBM contributors (bytes, trip-multiplied):")
    for key, nb, _ in hlo.breakdown(txt, top=args.top):
        print(f"  {nb:.3e}  {key}")
    ma = compiled.memory_analysis()
    print(f"\npeak/dev raw: {(ma.argument_size_in_bytes + ma.temp_size_in_bytes + ma.output_size_in_bytes - ma.alias_size_in_bytes)/2**30:.2f} GiB")


if __name__ == "__main__":
    main()

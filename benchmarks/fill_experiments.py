"""Render the §Roofline table from results/dryrun and splice it into
EXPERIMENTS.md (replaces ROOFLINE_TABLE_PLACEHOLDER or the previous table
between the markers)."""
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import roofline  # noqa: E402

BEGIN = "<!-- ROOFLINE:BEGIN -->"
END = "<!-- ROOFLINE:END -->"


def main():
    out = roofline.run("16x16")
    table = roofline.render(out)
    block = f"{BEGIN}\n{table}\n{END}"
    path = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    if "ROOFLINE_TABLE_PLACEHOLDER" in text:
        text = text.replace("ROOFLINE_TABLE_PLACEHOLDER", block)
    elif BEGIN in text:
        text = re.sub(re.escape(BEGIN) + r".*?" + re.escape(END), block,
                      text, flags=re.S)
    else:
        raise SystemExit("no insertion point in EXPERIMENTS.md")
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(out['rows'])} roofline rows into EXPERIMENTS.md")


if __name__ == "__main__":
    main()

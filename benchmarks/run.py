"""Benchmark orchestrator: one section per paper table/figure plus the
roofline aggregation.

    PYTHONPATH=src python -m benchmarks.run [--skip table4]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SECTIONS = [
    ("table3_strategies", "Paper Table 3 — strategy comparison "
                          "(Naive / P-L_B / P-L_R-D)"),
    ("table4_scalability", "Paper Table 4 — expert-parallel scalability"),
    ("table56_perfmodel", "Paper Tables 5+6, Fig. 8 — perf model & cost"),
    ("fig4_prestack", "Paper Fig. 4 — prestacked vs unstacked layout"),
    ("ablation_capacity", "Ablation — L_R capacity factor "
                          "(drop rate vs wasted FLOPs; L_B as endpoint)"),
    ("roofline", "Roofline terms per (arch x shape) from the dry-run"),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip", nargs="*", default=[])
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()

    failures = []
    for mod_name, title in SECTIONS:
        if mod_name in args.skip or (args.only and mod_name not in args.only):
            continue
        print(f"\n{'=' * 72}\n{title}\n{'=' * 72}", flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            out = mod.run()
            print(mod.render(out))
            print(f"[{mod_name} done in {time.time() - t0:.1f}s]", flush=True)
        except Exception:
            failures.append(mod_name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED sections: {failures}")
        return 1
    print("\nall benchmark sections completed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

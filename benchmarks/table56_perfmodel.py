"""Paper Tables 5+6 and Fig. 8: cost efficiency and estimated bounds.

Pure analytical reproduction from core/perf_model (validated unit-for-unit
in tests/test_perf_model.py) plus the RDMA NIC projections.
"""
from __future__ import annotations

from benchmarks.common import markdown_table, save_result
from repro.core import perf_model as pm


def run() -> dict:
    # microchunks=4 adds the a2a_pipelined overlap columns (beyond-paper:
    # what Table 6 would look like if expert comm hid behind expert compute)
    table6 = pm.scaling_table(microchunks=4)
    fig8 = {
        hw.name: [
            {"nodes": n, "tok_per_s": pm.estimate(pm.DBRX_TABLE1, hw, n).throughput}
            for n in (2, 3, 4, 6, 8)
        ]
        for hw in (pm.M2_ULTRA_10GBE, pm.M2_ULTRA_ROCE, pm.M2_ULTRA_IB)
    }
    table5 = {
        "databricks-8xh100": {
            "throughput": 112.5,
            "tp_per_usd": pm.cost_efficiency(112.5, 1, pm.DGX_H100x8)},
        "ours-2xm2ultra": {
            "throughput": 5.9,
            "tp_per_usd": pm.cost_efficiency(5.9, 2, pm.M2_ULTRA_10GBE)},
    }
    table5["_ratio"] = (table5["ours-2xm2ultra"]["tp_per_usd"]
                        / table5["databricks-8xh100"]["tp_per_usd"])
    out = {"table5": table5, "table6": table6, "fig8": fig8}
    save_result("table56_perfmodel", out)
    return out


def render(out: dict) -> str:
    t6 = markdown_table(
        ["#nodes", "Load (s)", "Comp (s)", "Lat (s)", "Trans (s)",
         "Bound (s)", "TP (tok/s)", "paper TP", "TP pipelined (m=4)"],
        [[r["nodes"], f"{r['load_s']:.3f}", f"{r['comp_s']:.3f}",
          f"{r['lat_s']:.3f}", f"{r['trans_s']:.3f}", f"{r['bound_s']:.3f}",
          f"{r['tokens_per_sec']:.1f}",
          {2: 9.7, 3: 10.4, 4: 12.3, 6: 13.9, 8: 14.2}[r["nodes"]],
          f"{r.get('tokens_per_sec_pipelined', float('nan')):.1f}"]
         for r in out["table6"]])
    t5 = markdown_table(
        ["solution", "TP (tok/s)", "TP/USD", "paper TP/USD"],
        [["databricks 8xH100", 112.5,
          f"{out['table5']['databricks-8xh100']['tp_per_usd']:.6f}", 0.000389],
         ["ours 2x M2 Ultra", 5.9,
          f"{out['table5']['ours-2xm2ultra']['tp_per_usd']:.6f}", 0.000447]])
    fig8 = markdown_table(
        ["#nodes"] + list(out["fig8"]),
        [[n] + [f"{out['fig8'][hw][i]['tok_per_s']:.1f}"
                for hw in out["fig8"]]
         for i, n in enumerate((2, 3, 4, 6, 8))])
    return (f"### Table 6 — estimated bounds (10 GbE)\n{t6}\n\n"
            f"### Table 5 — cost efficiency (ratio "
            f"{out['table5']['_ratio']:.2f}x, paper claims 1.15x)\n{t5}\n\n"
            f"### Fig. 8 — NIC projections (tok/s)\n{fig8}")


if __name__ == "__main__":
    print(render(run()))

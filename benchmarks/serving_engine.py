"""Serving-engine hot-loop benchmark: legacy vs redesigned engine.

Compares, on identical params / requests / config:

  * legacy   — the seed engine's behaviour: one batch-1 prefill jit call per
    admitted request, ``block_until_ready`` + host sync every decode step
    (``EngineConfig(batched_prefill=False, async_steps=False)``);
  * batched  — batched one-jit-call prefill, still synchronous stepping;
  * async    — batched prefill + async decode (the PR 1 production path):
    no per-step sync, device-side routing capture harvested at
    request-completion boundaries.  Buffer donation and the gather decode
    fast path are OFF — this row is the pre-zero-copy baseline;
  * zerocopy — async + cache donation (``EngineConfig.donate_buffers``, the
    paper's C1 analogue: the decode step aliases the KV cache in place) +
    the capacity-free gather decode path (``cfg.gather_decode_max_tk``,
    core/moe.gather_moe): the PR 2 production configuration;
  * unified  — the PR 3 production path: zerocopy + the unified
    token-budget step (``EngineConfig.unified_step``): chunked prefill and
    mixed prefill/decode batches in ONE jit program, admissions never
    stall decode;
  * paged    — the PR 4 production path: unified + the paged KV cache
    (``EngineConfig.paged``): one donated page pool + per-row block tables
    + the radix prefix cache (docs/DESIGN.md §7).  The throughput row
    compares the LAYOUT only (the warmup's cached prefix is cleared); the
    ``--shared-prefix-len`` round measures prefix reuse on purpose —
    requests sharing a system prompt skip its prefill entirely, gated on
    prefix-hit tokens >= the shared length and on the hit tokens exactly
    explaining the prefill-token gap vs the contiguous engine.

A quantized-weight-store round (``run_quant_ab``, skip with
``--skip-quant``; PR 5, docs/DESIGN.md §8) A/Bs the unified engine at
``weight_quant`` none vs int8 on wall tok/s and reported device weight
bytes.  Two gates: (a) the int8 store is argmax-token-IDENTICAL to the
fake-quant fp reference (an engine serving the pre-dequantized weights as
raw arrays — the machinery-correctness gate; raw-fp equality is NOT a
sound gate because int8 rounding shifts logits ~1e-2, far above greedy
tie gaps, so the raw-fp token agreement is *reported* instead), and
(b) weight bytes shrink >= 3.5x at int8-with-fp-router.

A Pallas paged-attention round (``run_paged_kernel_ab``, skip with
``--skip-paged-kernel``; PR 8, docs/DESIGN.md §11) A/Bs the paged engine's
reference virtual-cache gather against the block-table kernel
(``EngineConfig.paged_kernel``) on wall tok/s and the analytic
per-decode-step attention bytes-read, gated on identical greedy tokens.

A staggered-arrival round (``run_staggered``, skip with
``--skip-staggered``) A/Bs the two-program reference against the unified
scheduler on TTFT p50/p95 and decode-stall time — the latency metrics the
throughput table cannot show.  Under ``--equal-capacity`` every prompt is
pinned to exactly ``--prompt-len`` tokens so the padding-free unified
engine must be token-identical to the padded reference modes.

    PYTHONPATH=src python -m benchmarks.serving_engine \
        [--arch qwen3_moe_30b_a3b] [--requests 8] [--new-tokens 24]

Writes results/bench/serving_engine.json and, for the perf trajectory
across PRs, repo-root ``BENCH_serving.json`` (config, tok/s per engine
mode, schedule) — successive PRs read it as the machine-readable baseline.
Prints a markdown table.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import markdown_table, save_result
from repro.configs.base import get_config
from repro.serving.engine import EngineConfig, ServingEngine

# mode -> (EngineConfig overrides, gather decode fast path enabled)
MODES = {
    "legacy": (dict(batched_prefill=False, async_steps=False,
                    donate_buffers=False, unified_step=False), False),
    "batched": (dict(batched_prefill=True, async_steps=False,
                     donate_buffers=False, unified_step=False), False),
    "async": (dict(batched_prefill=True, async_steps=True,
                   donate_buffers=False, unified_step=False), False),
    "zerocopy": (dict(batched_prefill=True, async_steps=True,
                      donate_buffers=True, unified_step=False), True),
    # unified token-budget engine (PR 3): chunked prefill + mixed
    # prefill/decode batches in ONE jit program, admits never stall decode
    "unified": (dict(batched_prefill=True, async_steps=True,
                     donate_buffers=True, unified_step=True), True),
    # paged KV cache (PR 4): page pool + block tables + prefix cache —
    # the throughput row measures the LAYOUT only (the prefix tree is
    # cleared after warmup so no accidental reuse flatters it; the
    # shared-prefix round below measures reuse on purpose)
    "paged": (dict(batched_prefill=True, async_steps=True,
                   donate_buffers=True, unified_step=True, paged=True),
              True),
}

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_serving.json")


def make_engine(cfg, mode_kw, *, prompt_len, new_tokens, max_batch,
                chunk_len, page_size=8):
    return ServingEngine(cfg, EngineConfig(
        max_batch=max_batch, prefill_len=prompt_len,
        max_cache=prompt_len + new_tokens + 8, chunk_len=chunk_len,
        page_size=page_size, **mode_kw), rng=jax.random.PRNGKey(0))


def run_mode(cfg, mode_kw, *, requests, new_tokens, prompt_len, max_batch,
             chunk_len, page_size=8, seed=0, full_len=False):
    eng = make_engine(cfg, mode_kw, prompt_len=prompt_len,
                      new_tokens=new_tokens, max_batch=max_batch,
                      chunk_len=chunk_len, page_size=page_size)
    rng = np.random.default_rng(seed)
    # full_len pins every prompt at exactly prompt_len so the unified
    # (no-padding) engine is comparable token-for-token with the padded
    # reference modes (shorter prompts legitimately diverge: the reference
    # attends its zero padding)
    prompts = [rng.integers(0, cfg.vocab_size,
                            prompt_len if full_len else
                            int(rng.integers(prompt_len // 2, prompt_len + 1)))
               for _ in range(requests)]
    # warmup: compile prefill + decode traces outside the timed region,
    # then reset the accumulated stats so tok/s excludes compile time
    eng.submit(prompts[0], max_new_tokens=2)
    eng.run_until_done()
    if eng.paged:
        # drop the warmup prompt's cached pages: the throughput row must
        # compare LAYOUTS, not hand the paged engine a free prefix hit
        eng.prefix.clear()
    for k in eng.stats:
        eng.stats[k] = type(eng.stats[k])()

    t0 = time.perf_counter()
    for p in prompts:
        eng.submit(p, max_new_tokens=new_tokens)
    done = eng.run_until_done()
    wall = time.perf_counter() - t0
    assert len(done) >= requests, (len(done), requests)
    toks = requests * (prompt_len + new_tokens)
    tp = eng.throughput()
    return {
        "wall_s": wall,
        "tok_per_s_wall": toks / wall,
        "prefill_tok_per_s": tp["prefill_tok_per_s"],
        "decode_tok_per_s": tp["decode_tok_per_s"],
        "generated": {r.uid: list(r.generated) for r in done},
    }


def run_staggered(cfg, mode_kw, *, requests, new_tokens, prompt_len,
                  max_batch, chunk_len, stagger_steps=4, seed=0):
    """Staggered-arrival latency workload: requests trickle in every
    ``stagger_steps`` engine iterations while earlier requests decode, so
    every admission after the first hits in-flight decode rows.  Reports
    TTFT p50/p95 and decode-stall time — the metrics the unified scheduler
    exists to improve (reference mode runs a separate whole-prompt padded
    prefill program that stalls every active decode slot; unified mode
    interleaves prefill chunks into the decode iterations).

    Sync stepping is forced for every mode: TTFT is stamped at harvest
    boundaries, and async coalescing would charge deferred harvests to the
    first token (see ServingEngine.ttft)."""
    kw = dict(mode_kw, async_steps=False)
    eng = make_engine(cfg, kw, prompt_len=prompt_len, new_tokens=new_tokens,
                      max_batch=max_batch, chunk_len=chunk_len)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len)
               for _ in range(requests)]
    eng.submit(prompts[0], max_new_tokens=2)     # warmup (compile)
    eng.run_until_done()
    for k in eng.stats:
        eng.stats[k] = type(eng.stats[k])()

    t0 = time.perf_counter()
    pending = list(prompts)
    eng.submit(pending.pop(0), max_new_tokens=new_tokens)
    steps = 0
    while pending or eng.queue or any(s is not None for s in eng.slots):
        eng.step()
        steps += 1
        if pending and steps % stagger_steps == 0:
            eng.submit(pending.pop(0), max_new_tokens=new_tokens)
        if steps > 100_000:
            raise RuntimeError("staggered workload did not drain")
    eng.flush()
    wall = time.perf_counter() - t0
    tp = eng.throughput()
    # since=t0 excludes the warmup request's compile-time TTFT
    tt = eng.ttft(since=t0)
    return {
        "wall_s": wall,
        "ttft_p50_ms": tt["p50"] * 1e3,
        "ttft_p95_ms": tt["p95"] * 1e3,
        "decode_stall_ms": tp["decode_stall_s"] * 1e3,
        "tok_per_s_wall": requests * (prompt_len + new_tokens) / wall,
        "n_ttft": tt["n"],
    }


def run_shared_prefix(cfg, *, requests, new_tokens, prompt_len, max_batch,
                      chunk_len, page_size, shared_len, paged, seed=0):
    """Shared-system-prompt workload (PR 4 acceptance A/B): ``requests``
    prompts share their leading ``shared_len`` tokens; each is submitted
    after the previous completes, so the paged engine's prefix cache holds
    the shared pages when every follower arrives and its prefill shrinks
    to the distinct tail.  Reports per-request TTFT (sync stepping — the
    honest stamp), real prefill-token counts, and the paged engine's
    prefix/page statistics; the contiguous unified engine re-prefills the
    shared prefix every time and is the baseline."""
    kw = dict(batched_prefill=True, async_steps=False, donate_buffers=True,
              unified_step=True, paged=paged)
    eng = make_engine(cfg, kw, prompt_len=prompt_len, new_tokens=new_tokens,
                      max_batch=max_batch, chunk_len=chunk_len,
                      page_size=page_size)
    rng = np.random.default_rng(seed)
    sysp = rng.integers(0, cfg.vocab_size, shared_len)
    prompts = [np.concatenate([sysp, rng.integers(0, cfg.vocab_size,
                                                  prompt_len - shared_len)])
               for _ in range(requests)]
    # warmup on an UNRELATED prompt (compile only, no prefix seeding)
    eng.submit(rng.integers(0, cfg.vocab_size, prompt_len),
               max_new_tokens=2)
    eng.run_until_done()
    if eng.paged:
        eng.prefix.clear()
    for k in eng.stats:
        eng.stats[k] = type(eng.stats[k])()
    t0 = time.perf_counter()
    ttfts, gens = [], {}
    for p in prompts:
        uid = eng.submit(p, max_new_tokens=new_tokens)
        eng.run_until_done()
        req = eng._all[uid]
        ttfts.append(req.first_token_s - req.submit_s)
        gens[uid] = list(req.generated)
    wall = time.perf_counter() - t0
    out = {
        "wall_s": wall,
        "ttft_first_ms": ttfts[0] * 1e3,
        # followers are where prefix hits land: their mean TTFT is the
        # prefix-hit TTFT the perf model estimates (prefix_hit_ttft)
        "ttft_followers_mean_ms": 1e3 * sum(ttfts[1:]) / max(len(ttfts) - 1,
                                                             1),
        "prefill_tokens": eng.stats["prefill_tokens"],
        "generated": gens,
    }
    out.update({k: v for k, v in eng.paged_stats().items() if k != "paged"})
    return out


def run_preempt_ab(cfg, *, requests, new_tokens, prompt_len, max_batch,
                   chunk_len, page_size, seed=0):
    """Overcommit A/B (PR 7 acceptance): the paged engine at equal pool
    bytes with conservative lifetime admission vs overcommitted lazy
    admission + priority preemption.  The pool is sized to hold roughly
    half the concurrent lifetimes, so the conservative engine serializes
    admissions while the overcommitted one packs rows and preempts under
    growth pressure.  Reports admitted concurrency (``active_hwm``), TTFT
    p50/p95 (sync stepping — the honest stamp), and preempt/restore
    counts; gated on token equality A == B per request (greedy preemption
    + prefix-cache restore is invisible in the token stream).

    Capacity is forced non-binding inside this round: with a binding
    ``capacity_factor`` the per-iteration dispatch pool depends on WHICH
    rows are co-scheduled, so changing the admission schedule changes
    tokens for reasons unrelated to preemption (the same batch-capacity
    semantics that exempt ``legacy`` from token gates outside
    ``--equal-capacity``).  The preemption gate must isolate the
    preempt/restore machinery, so it runs in the no-drop regime."""
    from repro.serving.scheduler import lifetime_pages
    cfg = cfg.replace(capacity_factor=max(cfg.capacity_factor, 8.0))
    pool = max_batch * lifetime_pages(prompt_len, new_tokens,
                                      page_size) // 2
    kw = dict(batched_prefill=True, async_steps=False, donate_buffers=True,
              unified_step=True, paged=True)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len)
               for _ in range(requests)]
    out = {}
    for name, overcommit in (("conservative", False), ("overcommit", True)):
        eng = ServingEngine(cfg, EngineConfig(
            max_batch=max_batch, prefill_len=prompt_len,
            max_cache=prompt_len + new_tokens + 8, chunk_len=chunk_len,
            page_size=page_size, num_pages=pool, overcommit=overcommit,
            **kw), rng=jax.random.PRNGKey(0))
        eng.submit(prompts[0], max_new_tokens=2)       # compile warmup
        eng.run_until_done()
        eng.prefix.clear()
        for k in eng.stats:
            eng.stats[k] = type(eng.stats[k])()
        t0 = time.perf_counter()
        for p in prompts:
            eng.submit(p, max_new_tokens=new_tokens)
        done = eng.run_until_done()
        wall = time.perf_counter() - t0
        tt = eng.ttft(since=t0)
        rs = eng.resilience_stats()
        out[name] = {
            "wall_s": wall,
            "tok_per_s_wall": requests * (prompt_len + new_tokens) / wall,
            "ttft_p50_ms": tt["p50"] * 1e3,
            "ttft_p95_ms": tt["p95"] * 1e3,
            "active_hwm": rs["active_hwm"],
            "preemptions": rs["preemptions"],
            "restores": rs["restores"],
            "num_pages": pool,
            "generated": {r.uid: list(r.generated) for r in done},
        }
    # gate: preemption + restore never changes greedy tokens
    assert (out["overcommit"].pop("generated")
            == out["conservative"].pop("generated")), \
        "overcommit preempt/restore diverged from conservative admission"
    # gate: equal pool bytes, strictly more admitted concurrency
    assert (out["overcommit"]["active_hwm"]
            > out["conservative"]["active_hwm"]), \
        ("overcommit admitted no extra concurrency",
         out["overcommit"], out["conservative"])
    return out


def run_quant_ab(base_cfg, *, requests, new_tokens, prompt_len, max_batch,
                 chunk_len, repeat=1, seed=0):
    """Quantized weight store A/B (PR 5 acceptance): the unified engine at
    ``weight_quant='none'`` vs ``'int8'`` vs the fake-quant fp reference
    (raw params pre-dequantized from the int8 store).  Identical raw init
    params everywhere (same rng).  Gates: int8 == fake-quant reference
    token-for-token (the store's machinery is argmax-exact), and reported
    weight bytes shrink >= 3.5x.  Raw-fp agreement is reported, not gated
    — int8 rounding legitimately flips near-tie greedy tokens."""
    import jax as _jax

    from repro.core import quant
    from repro.models.model import build_model

    kw = dict(batched_prefill=True, async_steps=True, donate_buffers=True,
              unified_step=True)
    raw_params = build_model(base_cfg).init(_jax.random.PRNGKey(0))
    qcfg = base_cfg.replace(weight_quant="int8")
    ref_params = quant.dequantize_tree(quant.quantize_params(raw_params,
                                                             qcfg))
    runs = {"fp": (base_cfg, raw_params), "int8": (qcfg, raw_params),
            "int8-ref": (base_cfg, ref_params)}
    out = {}
    reps: dict[str, list] = {name: [] for name in runs}
    for _ in range(max(repeat, 1)):
        for name, (cfg, params) in runs.items():
            eng = ServingEngine(cfg, EngineConfig(
                max_batch=max_batch, prefill_len=prompt_len,
                max_cache=prompt_len + new_tokens + 8,
                chunk_len=chunk_len, **kw), params=params)
            rng = np.random.default_rng(seed)
            prompts = [rng.integers(0, cfg.vocab_size, prompt_len)
                       for _ in range(requests)]
            eng.submit(prompts[0], max_new_tokens=2)      # compile warmup
            eng.run_until_done()
            for k in eng.stats:
                eng.stats[k] = type(eng.stats[k])()
            t0 = time.perf_counter()
            for p in prompts:
                eng.submit(p, max_new_tokens=new_tokens)
            done = eng.run_until_done()
            wall = time.perf_counter() - t0
            reps[name].append({
                "wall_s": wall,
                "tok_per_s_wall": requests * (prompt_len + new_tokens) / wall,
                "memory": eng.memory_stats(),
                "generated": {r.uid: list(r.generated) for r in done},
            })
            assert reps[name][-1]["generated"] == reps[name][0]["generated"]
    for name in runs:
        out[name] = min(reps[name], key=lambda r: r["wall_s"])
    gens = {k: r.pop("generated") for k, r in out.items()}
    # gate (a): the quantized store is argmax-token-identical to the
    # fake-quant fp reference — every piece of PR-5 machinery (packing,
    # scales, qdot, scan slicing, engine plumbing, donation) is exact
    assert gens["int8"] == gens["int8-ref"], \
        "int8 store diverged from the fake-quant fp reference"
    # raw-fp agreement: reported honestly, never gated
    flat = lambda g: [t for uid in sorted(g) for t in g[uid]]
    a, b = flat(gens["int8"]), flat(gens["fp"])
    agree = sum(x == y for x, y in zip(a, b)) / max(len(a), 1)
    out["raw_fp_token_agreement"] = agree
    # gate (b): reported weight bytes shrink >= 3.5x (int8, fp router)
    ratio = (out["fp"]["memory"]["weight_bytes"]
             / out["int8"]["memory"]["weight_bytes"])
    out["weight_bytes_ratio"] = ratio
    assert ratio >= 3.5, f"int8 weight-bytes shrink {ratio:.2f}x < 3.5x"
    return out


def run_paged_kernel_ab(base_cfg, *, requests, new_tokens, prompt_len,
                        max_batch, chunk_len, page_size, repeat=1, seed=0):
    """Pallas paged-attention A/B (PR 8 acceptance): the paged engine with
    the reference virtual-cache gather vs the block-table kernel
    (``EngineConfig.paged_kernel``), identical params / prompts / pool
    geometry.  Gate: greedy token streams are IDENTICAL — the kernel's
    flash online-softmax over pages is the same attention, computed
    without materializing the (B, NB*page_size, Hkv, hd) virtual cache.
    Alongside wall tok/s, reports the analytic per-decode-step attention
    bytes-read of each path (core/perf_model.paged_attention_read_bytes):
    the gather path always reads the full block-table extent, the kernel
    only the live pages — the memory story CI's interpret-mode timing
    cannot show (Pallas interpret mode is a correctness harness, not a
    performance one; the wall-clock column is honest but only meaningful
    on a real TPU backend)."""
    from repro.core import perf_model

    kw = dict(batched_prefill=True, async_steps=True, donate_buffers=True,
              unified_step=True, paged=True)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, base_cfg.vocab_size, prompt_len)
               for _ in range(requests)]
    out = {}
    reps: dict[str, list] = {"gather": [], "kernel": []}
    for _ in range(max(repeat, 1)):
        for name, pk in (("gather", False), ("kernel", True)):
            eng = make_engine(base_cfg, dict(kw, paged_kernel=pk),
                              prompt_len=prompt_len, new_tokens=new_tokens,
                              max_batch=max_batch, chunk_len=chunk_len,
                              page_size=page_size)
            eng.submit(prompts[0], max_new_tokens=2)      # compile warmup
            eng.run_until_done()
            eng.prefix.clear()
            for k in eng.stats:
                eng.stats[k] = type(eng.stats[k])()
            t0 = time.perf_counter()
            for p in prompts:
                eng.submit(p, max_new_tokens=new_tokens)
            done = eng.run_until_done()
            wall = time.perf_counter() - t0
            # every row-step of the workload's decode trajectory (length
            # prompt_len..prompt_len+new_tokens-1 per request), NOT the
            # end-of-decode snapshot — at the last step every row fills
            # its block table and the paths read equal bytes trivially
            traj = [prompt_len + i for i in range(new_tokens)
                    for _ in range(requests)]
            rd = perf_model.paged_attention_read_bytes(
                base_cfg, lengths=traj, page_size=page_size,
                max_blocks=eng.max_blocks)
            reps[name].append({
                "wall_s": wall,
                "tok_per_s_wall": requests * (prompt_len + new_tokens) / wall,
                "attn_read_bytes_per_row_step": (
                    rd["kernel_bytes"] if pk else rd["gather_bytes"])
                    / len(traj),
                "generated": {r.uid: list(r.generated) for r in done},
            })
            assert reps[name][-1]["generated"] == reps[name][0]["generated"]
    for name in reps:
        out[name] = min(reps[name], key=lambda r: r["wall_s"])
    gens = {k: r.pop("generated") for k, r in out.items()}
    # the PR-8 gate: the kernel changes HOW attention reads the pool,
    # never which tokens come out
    assert gens["kernel"] == gens["gather"], \
        "paged-attention kernel diverged from the virtual-cache gather"
    out["attn_read_ratio"] = (out["gather"]["attn_read_bytes_per_row_step"]
                              / out["kernel"]["attn_read_bytes_per_row_step"])
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_moe_30b_a3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--equal-capacity", action="store_true",
                    help="raise capacity_factor so no tokens drop and all "
                         "modes must be token-identical")
    ap.add_argument("--repeat", type=int, default=3,
                    help="timed repetitions per mode; the fastest wall "
                         "clock is kept (token equality is asserted on "
                         "every repetition)")
    ap.add_argument("--note", default="",
                    help="free-form provenance note stored in "
                         "BENCH_serving.json (e.g. cross-PR baseline "
                         "measurements taken outside this run)")
    ap.add_argument("--chunk-len", type=int, default=16,
                    help="unified mode: prefill chunk / block width")
    ap.add_argument("--page-size", type=int, default=8,
                    help="paged mode: tokens per page (CI passes a value "
                         "that does not divide --prompt-len to cover "
                         "ragged paging)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="run the shared-system-prompt A/B round with this "
                         "many shared leading tokens (0 = skip)")
    ap.add_argument("--stagger-steps", type=int, default=4,
                    help="staggered workload: iterations between arrivals")
    ap.add_argument("--skip-staggered", action="store_true",
                    help="skip the staggered-arrival TTFT/stall A/B round")
    ap.add_argument("--skip-quant", action="store_true",
                    help="skip the quantized-weight-store A/B round "
                         "(fp vs int8 tok/s + weight bytes, PR 5 gates)")
    ap.add_argument("--skip-preempt", action="store_true",
                    help="skip the overcommit preemption A/B round "
                         "(conservative vs overcommitted admission at "
                         "equal pool bytes, PR 7 gates)")
    ap.add_argument("--skip-paged-kernel", action="store_true",
                    help="skip the Pallas paged-attention A/B round "
                         "(virtual-cache gather vs block-table kernel, "
                         "PR 8 gates)")
    args = ap.parse_args()
    if args.shared_prefix_len >= args.prompt_len:
        ap.error("--shared-prefix-len must be < --prompt-len")

    base_cfg = get_config(args.arch).reduced()
    if args.equal_capacity:
        base_cfg = base_cfg.replace(capacity_factor=8.0)
    # repetitions are interleaved ACROSS modes (rep-major, mode-minor) so a
    # machine slowing down or speeding up over the run biases every mode
    # equally; the fastest wall clock per mode is kept
    reps: dict[str, list] = {name: [] for name in MODES}
    for _ in range(max(args.repeat, 1)):
        for name, (kw, gather) in MODES.items():
            cfg = (base_cfg if gather
                   else base_cfg.replace(gather_decode_max_tk=0))
            reps[name].append(run_mode(cfg, kw, requests=args.requests,
                                       new_tokens=args.new_tokens,
                                       prompt_len=args.prompt_len,
                                       max_batch=args.max_batch,
                                       chunk_len=args.chunk_len,
                                       page_size=args.page_size,
                                       full_len=args.equal_capacity))
            # identical engines must generate identical tokens every rep
            assert reps[name][-1]["generated"] == reps[name][0]["generated"], \
                name
    results, rows = {}, []
    for name in MODES:
        r = min(reps[name], key=lambda rr: rr["wall_s"])
        results[name] = r
        rows.append([name, f"{r['wall_s']:.2f}", f"{r['tok_per_s_wall']:.1f}",
                     f"{r['prefill_tok_per_s']:.1f}",
                     f"{r['decode_tok_per_s']:.1f}"])

    # correctness gates: async must match sync batched token-for-token, and
    # zerocopy (donation aliases buffers but never changes values; the
    # gather path computes the same per-token MoE sum) must match async;
    # legacy matches too whenever capacity is not binding (with the default
    # capacity factor the pooled batch admits tokens a batch-1 dispatch
    # would drop — the batch-capacity semantics documented in
    # serving/engine.py), so compare legacy only under --equal-capacity
    gens = {k: r.pop("generated") for k, r in results.items()}
    assert gens["batched"] == gens["async"], "async diverged from sync"
    # NB: the gather fast path reassociates the per-token MoE sum (~1e-6
    # logit wobble vs dispatch), so zerocopy equality relies on the greedy
    # argmax never sitting on a tie at that scale.  Prompts are seeded and
    # jax-CPU is deterministic, so for a FIXED jax wheel this comparison is
    # reproducible, not flaky; if a jax upgrade ever flips a tie here,
    # re-seed the prompts rather than loosening the gate.
    assert gens["zerocopy"] == gens["async"], \
        "zerocopy (donation + gather decode) diverged from the baseline"
    if args.equal_capacity:
        assert gens["legacy"] == gens["batched"], \
            "modes diverged in the no-drop regime"
        # unified == two-program reference, token for token: full-length
        # prompts (padding-free) + non-binding capacity (chunk-local
        # dispatch pools) make the chunked/mixed-batch schedule exactly
        # token-neutral — the PR 3 acceptance gate, also run in CI
        assert gens["unified"] == gens["zerocopy"], \
            "unified step diverged from the two-program reference"
        # paged == contiguous unified, token for token: the page-pool +
        # block-table layout (with a page size that need not divide the
        # prompt length — CI passes one that doesn't) changes WHERE K/V
        # live, never the attended values — the PR 4 acceptance gate
        assert gens["paged"] == gens["unified"], \
            "paged cache diverged from the contiguous unified engine"

    speedup = (results["async"]["tok_per_s_wall"]
               / results["legacy"]["tok_per_s_wall"])
    speedup_zc = (results["zerocopy"]["tok_per_s_wall"]
                  / results["async"]["tok_per_s_wall"])
    speedup_uni = (results["unified"]["tok_per_s_wall"]
                   / results["zerocopy"]["tok_per_s_wall"])
    speedup_pg = (results["paged"]["tok_per_s_wall"]
                  / results["unified"]["tok_per_s_wall"])
    print(markdown_table(
        ["mode", "wall s", "tok/s (wall)", "prefill tok/s", "decode tok/s"],
        rows))
    print(f"\nasync+batched vs legacy speedup: {speedup:.2f}x")
    print(f"zerocopy (donation+gather) vs async speedup: {speedup_zc:.2f}x")
    print(f"unified vs zerocopy (throughput) : {speedup_uni:.2f}x")
    print(f"paged vs unified (layout only)   : {speedup_pg:.2f}x")
    results["speedup_async_vs_legacy"] = speedup
    results["speedup_zerocopy_vs_async"] = speedup_zc
    results["speedup_unified_vs_zerocopy"] = speedup_uni
    results["speedup_paged_vs_unified"] = speedup_pg

    # staggered-arrival latency A/B: two-program reference vs unified,
    # interleaved rounds, best (lowest) TTFT p95 kept per mode — the
    # latency story (TTFT under concurrent load, decode-stall time) that
    # wall-clock tok/s cannot show
    staggered = {}
    if not args.skip_staggered:
        srep: dict[str, list] = {"reference": [], "unified": []}
        for _ in range(max(args.repeat, 1)):
            for sname, mode in (("reference", "zerocopy"),
                                ("unified", "unified")):
                kw, gather = MODES[mode]
                cfg = (base_cfg if gather
                       else base_cfg.replace(gather_decode_max_tk=0))
                srep[sname].append(run_staggered(
                    cfg, kw, requests=args.requests,
                    new_tokens=args.new_tokens, prompt_len=args.prompt_len,
                    max_batch=args.max_batch, chunk_len=args.chunk_len,
                    stagger_steps=args.stagger_steps))
        for sname, rr in srep.items():
            staggered[sname] = min(rr, key=lambda r: r["ttft_p95_ms"])
        print("\nstaggered arrivals (sync stepping, full-length prompts):")
        print(markdown_table(
            ["mode", "TTFT p50 ms", "TTFT p95 ms", "stall ms", "tok/s"],
            [[sname, f"{r['ttft_p50_ms']:.1f}", f"{r['ttft_p95_ms']:.1f}",
              f"{r['decode_stall_ms']:.1f}", f"{r['tok_per_s_wall']:.1f}"]
             for sname, r in staggered.items()]))
        results["staggered"] = staggered

    # shared-system-prompt A/B (PR 4 acceptance): contiguous unified
    # re-prefills the shared prefix for every request; the paged engine's
    # prefix cache skips it.  Gates: token equality, and the paged engine
    # must have skipped at least the shared prefix's worth of prefill
    # (the hit tokens exactly explain the prefill-token gap).
    shared = {}
    if args.shared_prefix_len > 0:
        for sname, is_paged in (("contiguous", False), ("paged", True)):
            shared[sname] = run_shared_prefix(
                cfg=base_cfg, requests=args.requests,
                new_tokens=args.new_tokens, prompt_len=args.prompt_len,
                max_batch=args.max_batch, chunk_len=args.chunk_len,
                page_size=args.page_size,
                shared_len=args.shared_prefix_len, paged=is_paged)
        sg = {k: r.pop("generated") for k, r in shared.items()}
        assert sg["paged"] == sg["contiguous"], \
            "prefix-cache reuse changed tokens"
        hit = shared["paged"]["prefix_hit_tokens"]
        assert hit >= args.shared_prefix_len, \
            (hit, args.shared_prefix_len)
        assert (shared["contiguous"]["prefill_tokens"]
                - shared["paged"]["prefill_tokens"] == hit), shared
        print(f"\nshared system prompt ({args.shared_prefix_len} of "
              f"{args.prompt_len} tokens, {args.requests} sequential "
              f"requests):")
        print(markdown_table(
            ["mode", "TTFT req1 ms", "TTFT followers ms", "prefill toks",
             "hit toks", "hit rate"],
            [[sname, f"{r['ttft_first_ms']:.1f}",
              f"{r['ttft_followers_mean_ms']:.1f}",
              str(r["prefill_tokens"]),
              str(r.get("prefix_hit_tokens", 0)),
              f"{r.get('prefix_hit_rate', 0.0):.0%}"]
             for sname, r in shared.items()]))
        results["shared_prefix"] = shared

    # quantized weight store A/B (PR 5): fp vs int8 vs fake-quant
    # reference — argmax parity + >=3.5x weight-bytes shrink gated inside
    quant_ab = {}
    if not args.skip_quant:
        quant_ab = run_quant_ab(
            base_cfg, requests=args.requests, new_tokens=args.new_tokens,
            prompt_len=args.prompt_len, max_batch=args.max_batch,
            chunk_len=args.chunk_len, repeat=args.repeat)
        print(f"\nquantized weight store (unified engine, "
              f"block={base_cfg.weight_quant_block}):")
        print(markdown_table(
            ["mode", "wall s", "tok/s (wall)", "weight MB"],
            [[nm, f"{quant_ab[nm]['wall_s']:.2f}",
              f"{quant_ab[nm]['tok_per_s_wall']:.1f}",
              f"{quant_ab[nm]['memory']['weight_bytes'] / 1e6:.2f}"]
             for nm in ("fp", "int8", "int8-ref")]))
        print(f"weight bytes fp/int8: {quant_ab['weight_bytes_ratio']:.2f}x"
              f"  raw-fp token agreement: "
              f"{quant_ab['raw_fp_token_agreement']:.1%}  "
              f"(int8 == fake-quant reference: gated exact)")
        results["quant_ab"] = quant_ab
    # overcommit preemption A/B (PR 7): conservative lifetime admission vs
    # lazy overcommit + priority preemption at EQUAL pool bytes — token
    # equality and strictly-higher admitted concurrency gated inside
    preempt_ab = {}
    if not args.skip_preempt:
        preempt_ab = run_preempt_ab(
            base_cfg, requests=args.requests, new_tokens=args.new_tokens,
            prompt_len=args.prompt_len, max_batch=args.max_batch,
            chunk_len=args.chunk_len, page_size=args.page_size)
        print(f"\novercommit preemption (equal pool: "
              f"{preempt_ab['overcommit']['num_pages']} pages, sync "
              "stepping):")
        print(markdown_table(
            ["admission", "wall s", "tok/s", "TTFT p50 ms", "TTFT p95 ms",
             "active hwm", "preempts", "restores"],
            [[nm, f"{r['wall_s']:.2f}", f"{r['tok_per_s_wall']:.1f}",
              f"{r['ttft_p50_ms']:.1f}", f"{r['ttft_p95_ms']:.1f}",
              str(r["active_hwm"]), str(r["preemptions"]),
              str(r["restores"])]
             for nm, r in preempt_ab.items()]))
        results["preempt_ab"] = preempt_ab
    # Pallas paged-attention A/B (PR 8): virtual-cache gather vs the
    # block-table kernel — token equality gated inside; the bytes-read
    # column is the analytic memory story (interpret-mode wall clock on
    # CPU is a correctness harness, not a perf measurement)
    paged_kernel_ab = {}
    if not args.skip_paged_kernel:
        paged_kernel_ab = run_paged_kernel_ab(
            base_cfg, requests=args.requests, new_tokens=args.new_tokens,
            prompt_len=args.prompt_len, max_batch=args.max_batch,
            chunk_len=args.chunk_len, page_size=args.page_size,
            repeat=args.repeat)
        print(f"\npaged-attention kernel (page size {args.page_size}, "
              "tokens gated identical):")
        print(markdown_table(
            ["attention", "wall s", "tok/s", "attn MB/row-step"],
            [[nm, f"{r['wall_s']:.2f}", f"{r['tok_per_s_wall']:.1f}",
              f"{r['attn_read_bytes_per_row_step'] / 1e6:.3f}"]
             for nm, r in paged_kernel_ab.items()
             if isinstance(r, dict)]))
        print("attn bytes-read gather/kernel: "
              f"{paged_kernel_ab['attn_read_ratio']:.2f}x")
        results["paged_kernel_ab"] = paged_kernel_ab
    path = save_result("serving_engine", results)
    print(f"saved {path}")

    # repo-root perf trajectory: machine-readable baseline for the next PR
    bench = {
        "arch": args.arch,
        "schedule": base_cfg.expert_parallel,
        "config": {
            "requests": args.requests, "new_tokens": args.new_tokens,
            "prompt_len": args.prompt_len, "max_batch": args.max_batch,
            "chunk_len": args.chunk_len, "page_size": args.page_size,
            "shared_prefix_len": args.shared_prefix_len,
            "equal_capacity": bool(args.equal_capacity),
            "capacity_factor": base_cfg.capacity_factor,
            "gather_decode_max_tk": base_cfg.gather_decode_max_tk,
            "ep_microchunks": base_cfg.ep_microchunks,
        },
        "tok_per_s_wall": {k: results[k]["tok_per_s_wall"] for k in MODES},
        "decode_tok_per_s": {k: results[k]["decode_tok_per_s"]
                             for k in MODES},
        "speedup_async_vs_legacy": speedup,
        "speedup_zerocopy_vs_async": speedup_zc,
        "speedup_unified_vs_zerocopy": speedup_uni,
        "speedup_paged_vs_unified": speedup_pg,
    }
    if staggered:
        bench["staggered_ab"] = staggered
    if shared:
        bench["shared_prefix_ab"] = shared
    if quant_ab:
        bench["quant_ab"] = {
            "tok_per_s_wall": {nm: quant_ab[nm]["tok_per_s_wall"]
                               for nm in ("fp", "int8", "int8-ref")},
            "weight_bytes": {nm: quant_ab[nm]["memory"]["weight_bytes"]
                             for nm in ("fp", "int8", "int8-ref")},
            "weight_bytes_ratio": quant_ab["weight_bytes_ratio"],
            "raw_fp_token_agreement": quant_ab["raw_fp_token_agreement"],
            "weight_quant_block": base_cfg.weight_quant_block,
        }
    if preempt_ab:
        bench["preempt_ab"] = preempt_ab
    if paged_kernel_ab:
        bench["paged_kernel_ab"] = paged_kernel_ab
    if args.note:
        bench["note"] = args.note
    with open(BENCH_JSON, "w") as f:
        json.dump(bench, f, indent=1, default=float)
        f.write("\n")
    print(f"saved {os.path.abspath(BENCH_JSON)}")
    return results


if __name__ == "__main__":
    main()

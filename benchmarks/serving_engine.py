"""Serving-engine hot-loop benchmark: legacy vs redesigned engine.

Compares, on identical params / requests / config:

  * legacy   — the seed engine's behaviour: one batch-1 prefill jit call per
    admitted request, ``block_until_ready`` + host sync every decode step
    (``EngineConfig(batched_prefill=False, async_steps=False)``);
  * batched  — batched one-jit-call prefill, still synchronous stepping;
  * async    — batched prefill + async decode (the PR 1 production path):
    no per-step sync, device-side routing capture harvested at
    request-completion boundaries.  Buffer donation and the gather decode
    fast path are OFF — this row is the pre-zero-copy baseline;
  * zerocopy — async + cache donation (``EngineConfig.donate_buffers``, the
    paper's C1 analogue: the decode step aliases the KV cache in place) +
    the capacity-free gather decode path (``cfg.gather_decode_max_tk``,
    core/moe.gather_moe): the current production configuration.

    PYTHONPATH=src python -m benchmarks.serving_engine \
        [--arch qwen3_moe_30b_a3b] [--requests 8] [--new-tokens 24]

Writes results/bench/serving_engine.json and, for the perf trajectory
across PRs, repo-root ``BENCH_serving.json`` (config, tok/s per engine
mode, schedule) — successive PRs read it as the machine-readable baseline.
Prints a markdown table.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import markdown_table, save_result
from repro.configs.base import get_config
from repro.serving.engine import EngineConfig, ServingEngine

# mode -> (EngineConfig overrides, gather decode fast path enabled)
MODES = {
    "legacy": (dict(batched_prefill=False, async_steps=False,
                    donate_buffers=False), False),
    "batched": (dict(batched_prefill=True, async_steps=False,
                     donate_buffers=False), False),
    "async": (dict(batched_prefill=True, async_steps=True,
                   donate_buffers=False), False),
    "zerocopy": (dict(batched_prefill=True, async_steps=True,
                      donate_buffers=True), True),
}

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_serving.json")


def run_mode(cfg, mode_kw, *, requests, new_tokens, prompt_len, max_batch,
             seed=0):
    eng = ServingEngine(cfg, EngineConfig(
        max_batch=max_batch, prefill_len=prompt_len,
        max_cache=prompt_len + new_tokens + 8, **mode_kw),
        rng=jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(prompt_len // 2, prompt_len + 1)))
               for _ in range(requests)]
    # warmup: compile prefill + decode traces outside the timed region,
    # then reset the accumulated stats so tok/s excludes compile time
    eng.submit(prompts[0], max_new_tokens=2)
    eng.run_until_done()
    for k in eng.stats:
        eng.stats[k] = type(eng.stats[k])()

    t0 = time.perf_counter()
    for p in prompts:
        eng.submit(p, max_new_tokens=new_tokens)
    done = eng.run_until_done()
    wall = time.perf_counter() - t0
    assert len(done) >= requests, (len(done), requests)
    toks = requests * (prompt_len + new_tokens)
    tp = eng.throughput()
    return {
        "wall_s": wall,
        "tok_per_s_wall": toks / wall,
        "prefill_tok_per_s": tp["prefill_tok_per_s"],
        "decode_tok_per_s": tp["decode_tok_per_s"],
        "generated": {r.uid: list(r.generated) for r in done},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_moe_30b_a3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--equal-capacity", action="store_true",
                    help="raise capacity_factor so no tokens drop and all "
                         "modes must be token-identical")
    ap.add_argument("--repeat", type=int, default=3,
                    help="timed repetitions per mode; the fastest wall "
                         "clock is kept (token equality is asserted on "
                         "every repetition)")
    ap.add_argument("--note", default="",
                    help="free-form provenance note stored in "
                         "BENCH_serving.json (e.g. cross-PR baseline "
                         "measurements taken outside this run)")
    args = ap.parse_args()

    base_cfg = get_config(args.arch).reduced()
    if args.equal_capacity:
        base_cfg = base_cfg.replace(capacity_factor=8.0)
    # repetitions are interleaved ACROSS modes (rep-major, mode-minor) so a
    # machine slowing down or speeding up over the run biases every mode
    # equally; the fastest wall clock per mode is kept
    reps: dict[str, list] = {name: [] for name in MODES}
    for _ in range(max(args.repeat, 1)):
        for name, (kw, gather) in MODES.items():
            cfg = (base_cfg if gather
                   else base_cfg.replace(gather_decode_max_tk=0))
            reps[name].append(run_mode(cfg, kw, requests=args.requests,
                                       new_tokens=args.new_tokens,
                                       prompt_len=args.prompt_len,
                                       max_batch=args.max_batch))
            # identical engines must generate identical tokens every rep
            assert reps[name][-1]["generated"] == reps[name][0]["generated"], \
                name
    results, rows = {}, []
    for name in MODES:
        r = min(reps[name], key=lambda rr: rr["wall_s"])
        results[name] = r
        rows.append([name, f"{r['wall_s']:.2f}", f"{r['tok_per_s_wall']:.1f}",
                     f"{r['prefill_tok_per_s']:.1f}",
                     f"{r['decode_tok_per_s']:.1f}"])

    # correctness gates: async must match sync batched token-for-token, and
    # zerocopy (donation aliases buffers but never changes values; the
    # gather path computes the same per-token MoE sum) must match async;
    # legacy matches too whenever capacity is not binding (with the default
    # capacity factor the pooled batch admits tokens a batch-1 dispatch
    # would drop — the batch-capacity semantics documented in
    # serving/engine.py), so compare legacy only under --equal-capacity
    gens = {k: r.pop("generated") for k, r in results.items()}
    assert gens["batched"] == gens["async"], "async diverged from sync"
    # NB: the gather fast path reassociates the per-token MoE sum (~1e-6
    # logit wobble vs dispatch), so zerocopy equality relies on the greedy
    # argmax never sitting on a tie at that scale.  Prompts are seeded and
    # jax-CPU is deterministic, so for a FIXED jax wheel this comparison is
    # reproducible, not flaky; if a jax upgrade ever flips a tie here,
    # re-seed the prompts rather than loosening the gate.
    assert gens["zerocopy"] == gens["async"], \
        "zerocopy (donation + gather decode) diverged from the baseline"
    if args.equal_capacity:
        assert gens["legacy"] == gens["batched"], \
            "modes diverged in the no-drop regime"

    speedup = (results["async"]["tok_per_s_wall"]
               / results["legacy"]["tok_per_s_wall"])
    speedup_zc = (results["zerocopy"]["tok_per_s_wall"]
                  / results["async"]["tok_per_s_wall"])
    print(markdown_table(
        ["mode", "wall s", "tok/s (wall)", "prefill tok/s", "decode tok/s"],
        rows))
    print(f"\nasync+batched vs legacy speedup: {speedup:.2f}x")
    print(f"zerocopy (donation+gather) vs async speedup: {speedup_zc:.2f}x")
    results["speedup_async_vs_legacy"] = speedup
    results["speedup_zerocopy_vs_async"] = speedup_zc
    path = save_result("serving_engine", results)
    print(f"saved {path}")

    # repo-root perf trajectory: machine-readable baseline for the next PR
    bench = {
        "arch": args.arch,
        "schedule": base_cfg.expert_parallel,
        "config": {
            "requests": args.requests, "new_tokens": args.new_tokens,
            "prompt_len": args.prompt_len, "max_batch": args.max_batch,
            "equal_capacity": bool(args.equal_capacity),
            "capacity_factor": base_cfg.capacity_factor,
            "gather_decode_max_tk": base_cfg.gather_decode_max_tk,
            "ep_microchunks": base_cfg.ep_microchunks,
        },
        "tok_per_s_wall": {k: results[k]["tok_per_s_wall"] for k in MODES},
        "decode_tok_per_s": {k: results[k]["decode_tok_per_s"]
                             for k in MODES},
        "speedup_async_vs_legacy": speedup,
        "speedup_zerocopy_vs_async": speedup_zc,
    }
    if args.note:
        bench["note"] = args.note
    with open(BENCH_JSON, "w") as f:
        json.dump(bench, f, indent=1, default=float)
        f.write("\n")
    print(f"saved {os.path.abspath(BENCH_JSON)}")
    return results


if __name__ == "__main__":
    main()

"""Serving-engine hot-loop benchmark: legacy vs redesigned engine.

Compares, on identical params / requests / config:

  * legacy  — the seed engine's behaviour: one batch-1 prefill jit call per
    admitted request, ``block_until_ready`` + host sync every decode step
    (``EngineConfig(batched_prefill=False, async_steps=False)``);
  * batched — batched one-jit-call prefill, still synchronous stepping;
  * async   — batched prefill + async decode (the production path): no
    per-step sync, device-side routing capture harvested at
    request-completion boundaries.

    PYTHONPATH=src python -m benchmarks.serving_engine \
        [--arch qwen3_moe_30b_a3b] [--requests 8] [--new-tokens 24]

Writes results/bench/serving_engine.json and prints a markdown table.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import markdown_table, save_result
from repro.configs.base import get_config
from repro.serving.engine import EngineConfig, ServingEngine

MODES = {
    "legacy": dict(batched_prefill=False, async_steps=False),
    "batched": dict(batched_prefill=True, async_steps=False),
    "async": dict(batched_prefill=True, async_steps=True),
}


def run_mode(cfg, mode_kw, *, requests, new_tokens, prompt_len, max_batch,
             seed=0):
    eng = ServingEngine(cfg, EngineConfig(
        max_batch=max_batch, prefill_len=prompt_len,
        max_cache=prompt_len + new_tokens + 8, **mode_kw),
        rng=jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(prompt_len // 2, prompt_len + 1)))
               for _ in range(requests)]
    # warmup: compile prefill + decode traces outside the timed region,
    # then reset the accumulated stats so tok/s excludes compile time
    eng.submit(prompts[0], max_new_tokens=2)
    eng.run_until_done()
    for k in eng.stats:
        eng.stats[k] = type(eng.stats[k])()

    t0 = time.perf_counter()
    for p in prompts:
        eng.submit(p, max_new_tokens=new_tokens)
    done = eng.run_until_done()
    wall = time.perf_counter() - t0
    assert len(done) >= requests, (len(done), requests)
    toks = requests * (prompt_len + new_tokens)
    tp = eng.throughput()
    return {
        "wall_s": wall,
        "tok_per_s_wall": toks / wall,
        "prefill_tok_per_s": tp["prefill_tok_per_s"],
        "decode_tok_per_s": tp["decode_tok_per_s"],
        "generated": {r.uid: list(r.generated) for r in done},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_moe_30b_a3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--equal-capacity", action="store_true",
                    help="raise capacity_factor so no tokens drop and all "
                         "three modes must be token-identical")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.equal_capacity:
        cfg = cfg.replace(capacity_factor=8.0)
    results, rows = {}, []
    for name, kw in MODES.items():
        r = run_mode(cfg, kw, requests=args.requests,
                     new_tokens=args.new_tokens, prompt_len=args.prompt_len,
                     max_batch=args.max_batch)
        results[name] = r
        rows.append([name, f"{r['wall_s']:.2f}", f"{r['tok_per_s_wall']:.1f}",
                     f"{r['prefill_tok_per_s']:.1f}",
                     f"{r['decode_tok_per_s']:.1f}"])

    # correctness gates: async must match sync batched token-for-token;
    # legacy matches too whenever capacity is not binding (with the default
    # capacity factor the pooled batch admits tokens a batch-1 dispatch
    # would drop — the batch-capacity semantics documented in
    # serving/engine.py), so compare legacy only under --equal-capacity
    gens = {k: r.pop("generated") for k, r in results.items()}
    assert gens["batched"] == gens["async"], "async diverged from sync"
    if args.equal_capacity:
        assert gens["legacy"] == gens["batched"], \
            "modes diverged in the no-drop regime"

    speedup = (results["async"]["tok_per_s_wall"]
               / results["legacy"]["tok_per_s_wall"])
    print(markdown_table(
        ["mode", "wall s", "tok/s (wall)", "prefill tok/s", "decode tok/s"],
        rows))
    print(f"\nasync+batched vs legacy speedup: {speedup:.2f}x")
    results["speedup_async_vs_legacy"] = speedup
    path = save_result("serving_engine", results)
    print(f"saved {path}")
    return results


if __name__ == "__main__":
    main()

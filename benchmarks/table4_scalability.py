"""Paper Table 4: P-L_R-D scalability across 2..8-way expert parallelism.

Runs in a subprocess with 8 emulated host devices: the reduced DBRX decode
step under EP degrees 1/2/4/8.  Reports wall-clock (noisy on CPU, indicative
only), per-shard expert FLOPs from the HLO (the paper's 'MoE time' driver —
decreases with nodes) and collective bytes (the paper's 'Comm.' share —
grows with nodes).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import markdown_table, save_result

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
from benchmarks.common import time_fn
from repro.configs.base import get_config
from repro.launch import hlo
from repro.models.model import build_model

base = get_config("dbrx").reduced().replace(
    moe_strategy="dispatch", expert_parallel="decentralized",
    num_experts=16, num_experts_padded=16, experts_per_token=4)
b = 8
out = {}
for ep in (1, 2, 4, 8):
    mesh = None if ep == 1 else jax.make_mesh((8 // ep, ep), ("data", "model"))
    model = build_model(base)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(b, 64)
    step = {"tokens": jnp.zeros((b, 1), jnp.int32),
            "lengths": jnp.full((b,), 8, jnp.int32)}
    fn = jax.jit(lambda p, c, s: model.decode_step(p, c, s, mesh))
    t = time_fn(fn, params, cache, step, iters=6)
    totals = hlo.analyze(fn.lower(params, cache, step).compile().as_text())
    out[str(ep)] = {
        "decode_s": t,
        "hlo_flops_per_device": totals.flops,
        "collective_bytes_per_device": totals.collective_bytes,
        "collectives": dict(totals.coll),
    }
print("JSON:" + json.dumps(out))
"""


def run() -> dict:
    env = dict(os.environ)
    here = os.path.dirname(__file__)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "..", "src"), os.path.join(here, ".."),
         env.get("PYTHONPATH", "")])
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _CHILD],
                          capture_output=True, text=True, timeout=1200,
                          env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    line = [l for l in proc.stdout.splitlines() if l.startswith("JSON:")][0]
    rows = json.loads(line[5:])

    # paper Table 4 mechanism: per-node expert work decreases with nodes
    assert rows["8"]["hlo_flops_per_device"] < rows["1"]["hlo_flops_per_device"]
    rows["_meta"] = {
        "paper_table4": {2: 6.1, 3: 6.5, 4: 7.0},
        "note": "CPU wall-clock indicative; FLOPs/device and collective "
                "bytes are deterministic HLO measurements",
    }
    save_result("table4_scalability", rows)
    return rows


def render(rows: dict) -> str:
    hdr = ["EP degree", "decode s/step (CPU)", "expert FLOPs/device",
           "collective B/device"]
    body = [[ep, f"{v['decode_s']*1e3:.1f} ms",
             f"{v['hlo_flops_per_device']:.3g}",
             f"{v['collective_bytes_per_device']:.3g}"]
            for ep, v in sorted(rows.items()) if not ep.startswith("_")]
    return markdown_table(hdr, body)


if __name__ == "__main__":
    print(render(run()))

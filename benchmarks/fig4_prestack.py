"""Paper Fig. 4 / §3.2: stacked vs unstacked weight layout.

On Apple/Metal the unstacked layout triggers driver re-wiring; on TPU/XLA
the analogous costs are program size and dispatch overhead: the unstacked
(python-loop) layout emits O(L) HLO while prestacked scans one body.  We
measure, at matched workload (the paper's Algorithm 2: L layers x 3
matmuls):

  * HLO instruction count (program size),
  * trace+lower+compile wall time,
  * steady-state execution wall time.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import markdown_table, save_result, time_fn


def build(n_layers: int, n_mpl: int, n: int, stacked: bool):
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (n_layers, n_mpl, n, n), jnp.float32) * 0.05
    x = jnp.ones((1, n), jnp.float32)

    if stacked:
        def f(x, w):
            def layer(c, wl):
                for j in range(n_mpl):
                    c = c @ wl[j]
                return c, ()
            return jax.lax.scan(layer, x, w)[0]
    else:
        ws = [[jnp.asarray(w[i, j]) for j in range(n_mpl)]
              for i in range(n_layers)]

        def f(x, _):
            for i in range(n_layers):
                for j in range(n_mpl):
                    x = x @ ws[i][j]
            return x
    return f, x, w


def run(n_layers: int = 40, n_mpl: int = 3, n: int = 256) -> dict:
    out = {}
    for stacked in (False, True):
        f, x, w = build(n_layers, n_mpl, n, stacked)
        jf = jax.jit(f)
        t0 = time.perf_counter()
        lowered = jf.lower(x, w)
        compiled = lowered.compile()
        compile_s = time.perf_counter() - t0
        hlo_lines = sum(1 for l in compiled.as_text().splitlines()
                        if "=" in l and "%" in l)
        exec_s = time_fn(jf, x, w, iters=10)
        out["prestacked" if stacked else "unstacked"] = {
            "compile_s": compile_s,
            "hlo_instructions": hlo_lines,
            "exec_s": exec_s,
        }
    out["_meta"] = {
        "workload": f"{n_layers} layers x {n_mpl} matmuls of {n}x{n}",
        "paper_finding": "prestacking keeps execution stable; unstacked "
                         "layout pays repeated per-layer overhead "
                         "(driver re-wiring on Metal; program size/dispatch "
                         "on XLA)",
        "hlo_ratio": out["unstacked"]["hlo_instructions"]
        / out["prestacked"]["hlo_instructions"],
    }
    assert out["unstacked"]["hlo_instructions"] \
        > 2 * out["prestacked"]["hlo_instructions"]
    save_result("fig4_prestack", out)
    return out


def render(out: dict) -> str:
    hdr = ["layout", "HLO instrs", "compile (s)", "exec (s)"]
    body = [[k, v["hlo_instructions"], f"{v['compile_s']:.2f}",
             f"{v['exec_s']*1e3:.1f} ms"]
            for k, v in out.items() if not k.startswith("_")]
    return markdown_table(hdr, body)


if __name__ == "__main__":
    print(render(run()))

"""Paper Table 3: Naive vs P-L_B vs P-L_R-D.

On this CPU container we reproduce the *mechanism* of Table 3 with two
complementary measurements on the reduced DBRX config:

  1. wall-clock decode throughput per strategy (single device), and
  2. deterministic cost counters from the lowered HLO — expert FLOPs per
     token (waste: L_B computes all E experts, L_R computes ~top-k) and
     collectives per layer (centralized = 2, decentralized = 1),

which are exactly the two levers the paper attributes its 1.7x / 5.2x MoE
speedups to (§4.2, §4.3).  The collective count is measured on a host-device
mesh in a subprocess (see run.py) — here we report FLOPs + throughput.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result, time_fn
from repro.configs.base import get_config
from repro.launch import hlo
from repro.models.model import build_model

STRATEGIES = {
    "naive":   dict(prestack=False, moe_strategy="dispatch",
                    expert_parallel="centralized"),
    "P-L_B":   dict(prestack=True, moe_strategy="dense",
                    expert_parallel="centralized"),
    "P-L_R-D": dict(prestack=True, moe_strategy="dispatch",
                    expert_parallel="decentralized"),
}


def run(iters: int = 8) -> dict:
    # reduced dims but the paper's true expert arithmetic (16 experts, top-4)
    # and a realistic decode batch so capacity dispatch beats busy-full
    # loading on FLOPs exactly as in Table 3
    base = get_config("dbrx").reduced().replace(
        num_experts=16, num_experts_padded=16, experts_per_token=4)
    b, steps_cache = 32, 64
    rows = {}
    for name, kw in STRATEGIES.items():
        cfg = base.replace(**kw)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(b, steps_cache)
        step = {"tokens": jnp.zeros((b, 1), jnp.int32),
                "lengths": jnp.full((b,), 8, jnp.int32)}

        fn = jax.jit(lambda p, c, s: model.decode_step(p, c, s))
        t = time_fn(fn, params, cache, step, iters=iters)
        lowered = fn.lower(params, cache, step)
        totals = hlo.analyze(lowered.compile().as_text())
        rows[name] = {
            "decode_s_per_step": t,
            "decode_tok_per_s": b / t,
            "hlo_flops": totals.flops,
            "hlo_flops_per_token": totals.flops / b,
        }
    # mechanism assertions (Table 3's causes)
    # L_B computes every expert -> more FLOPs than dispatch strategies
    assert rows["P-L_B"]["hlo_flops"] > rows["P-L_R-D"]["hlo_flops"], rows
    rows["_meta"] = {
        "config": base.name,
        "paper_table3": {"naive": 1.2, "P-L_B": 2.1, "P-L_R-D": 6.1},
        "flops_ratio_LB_over_LRD": rows["P-L_B"]["hlo_flops"]
        / rows["P-L_R-D"]["hlo_flops"],
    }
    save_result("table3_strategies", rows)
    return rows


def render(rows: dict) -> str:
    from benchmarks.common import markdown_table
    hdr = ["strategy", "decode tok/s (CPU, reduced)", "HLO FLOPs/token",
           "paper gen TP (tokens/s)"]
    paper = rows["_meta"]["paper_table3"]
    body = [[k,
             f"{v['decode_tok_per_s']:.2f}",
             f"{v['hlo_flops_per_token']:.3g}",
             paper[k]]
            for k, v in rows.items() if not k.startswith("_")]
    return markdown_table(hdr, body)


if __name__ == "__main__":
    print(render(run()))

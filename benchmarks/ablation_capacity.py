"""Ablation: capacity factor in the L_R (dispatch) strategy.

The paper's Router-Aided Dynamic Loading equalizes per-node work to the
max selected count; the SPMD realization uses a static capacity C.  This
ablation quantifies the trade-off the capacity factor controls:

  * drop rate — routing decisions above C are dropped (quality risk),
  * expert FLOPs — C slots are computed whether full or padded (waste),

on the paper's 16-expert/top-4 arithmetic across batch sizes, plus the
L_B (dense) endpoint for reference: L_B is capacity_factor = E/k with
zero drops, i.e. the paper's two §4.2 strategies are the endpoints of
this curve.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import markdown_table, save_result
from repro.core import moe, router


def drop_rate(top_idx, num_experts: int, capacity: int) -> float:
    """Fraction of (token, k) routing decisions that exceed capacity."""
    _, _, slot_of = moe.make_dispatch_plan(
        top_idx, num_experts, 0, num_experts, capacity)
    nbuf = num_experts * capacity
    return float(jnp.mean(slot_of == nbuf))


def run() -> dict:
    e, k = 16, 4
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, e)) * 0.5      # mildly skewed router
    out = {}
    for t in (64, 256, 1024):
        x = jax.random.normal(jax.random.fold_in(key, t), (t, 64))
        r = router.route(w, x, k)
        rows = {}
        for cf in (1.0, 1.25, 1.5, 2.0, 4.0):
            cap = moe.round_capacity(t, k, e, cf)
            rows[cf] = {
                "capacity": cap,
                "drop_rate": drop_rate(r.top_idx, e, cap),
                "slot_flops_ratio": e * cap / (t * k),  # computed/needed
            }
        # L_B endpoint: every expert computes every token
        rows["dense(L_B)"] = {"capacity": t, "drop_rate": 0.0,
                              "slot_flops_ratio": e / k}
        out[str(t)] = rows
    save_result("ablation_capacity", out)
    return out


def render(out: dict) -> str:
    hdr = ["tokens", "capacity factor", "capacity", "drop rate",
           "computed/needed FLOPs"]
    body = []
    for t, rows in out.items():
        for cf, v in rows.items():
            body.append([t, cf, v["capacity"], f"{v['drop_rate']:.3f}",
                         f"{v['slot_flops_ratio']:.2f}x"])
    return markdown_table(hdr, body)


if __name__ == "__main__":
    print(render(run()))
